//! In-tree property-testing runner (proptest is unavailable offline).
//!
//! Minimal but honest: seeded generation, configurable case count, and
//! greedy input shrinking on failure. Used by the `proptests.rs`
//! integration suite to check the paper's invariants over thousands of
//! random instances.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries lack the libxla rpath in this
//! // offline image; the same code runs in rust/tests/proptests.rs)
//! use mergeflow::testutil::{Prop, sorted_vec};
//! Prop::new(0xDEAD_BEEF).cases(200).run(
//!     |rng| sorted_vec(rng, 0..100, 0..50),
//!     |v| v.windows(2).all(|w| w[0] <= w[1]),
//! );
//! ```

use crate::rng::Xoshiro256;

/// Property runner: generates `cases` inputs from a seeded RNG, checks
/// the property, and shrinks on failure.
#[derive(Debug, Clone)]
pub struct Prop {
    seed: u64,
    cases: usize,
}

impl Prop {
    /// New runner with the given seed (printed on failure for replay).
    pub fn new(seed: u64) -> Self {
        Self { seed, cases: 100 }
    }

    /// Set the number of generated cases.
    pub fn cases(mut self, cases: usize) -> Self {
        self.cases = cases;
        self
    }

    /// Run `check` on `cases` inputs produced by `gen`. On failure,
    /// greedily shrinks via [`Shrink`] and panics with the minimal
    /// counterexample found.
    pub fn run<T, G, C>(&self, mut generate: G, check: C)
    where
        T: Shrink + std::fmt::Debug,
        G: FnMut(&mut Xoshiro256) -> T,
        C: Fn(&T) -> bool,
    {
        let mut rng = Xoshiro256::seeded(self.seed);
        for case in 0..self.cases {
            let input = generate(&mut rng);
            if !check(&input) {
                let minimal = shrink_loop(input, &check);
                panic!(
                    "property failed (seed={:#x}, case={case}); minimal counterexample: {minimal:?}",
                    self.seed
                );
            }
        }
    }
}

/// Greedy shrink: repeatedly take the first shrink candidate that still
/// fails, until none fails.
fn shrink_loop<T: Shrink + std::fmt::Debug, C: Fn(&T) -> bool>(mut failing: T, check: &C) -> T {
    let mut budget = 10_000usize; // hard cap against pathological shrinkers
    'outer: while budget > 0 {
        for cand in failing.shrink_candidates() {
            budget -= 1;
            if !check(&cand) {
                failing = cand;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    failing
}

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized {
    /// Candidate shrinks, roughly in decreasing aggressiveness.
    fn shrink_candidates(&self) -> Vec<Self>;
}

impl Shrink for Vec<i64> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // Halves (skip for n == 1: the upper "half" would be an
        // identical clone, a no-op candidate that stalls the shrinker).
        if n > 1 {
            out.push(self[..n / 2].to_vec());
            out.push(self[n / 2..].to_vec());
        }
        // Drop one element (first, middle, last).
        for idx in [0, n / 2, n - 1] {
            if idx < n {
                let mut v = self.clone();
                v.remove(idx);
                out.push(v);
            }
        }
        // Move values toward zero.
        if let Some(first_nonzero) = self.iter().position(|&x| x != 0) {
            let mut v = self.clone();
            v[first_nonzero] /= 2;
            out.push(v);
        }
        out
    }
}

impl Shrink for Vec<Vec<i64>> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // Halve the run count (n == 1 would just clone the original,
        // which stalls the greedy shrinker on a no-op candidate).
        if n > 1 {
            out.push(self[..n / 2].to_vec());
            out.push(self[n / 2..].to_vec());
        }
        // Drop one run (first, middle, last).
        for idx in [0, n / 2, n - 1] {
            if idx < n {
                let mut v = self.clone();
                v.remove(idx);
                out.push(v);
            }
        }
        // Shrink the first non-empty run in place.
        if let Some(i) = self.iter().position(|r| !r.is_empty()) {
            for cand in self[i].shrink_candidates() {
                let mut v = self.clone();
                v[i] = cand;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(
            self.1
                .shrink_candidates()
                .into_iter()
                .map(|b| (self.0.clone(), b)),
        );
        out
    }
}

impl<A, B, C> Shrink for (A, B, C)
where
    A: Shrink + Clone,
    B: Shrink + Clone,
    C: Shrink + Clone,
{
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink_candidates()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink_candidates()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

impl Shrink for usize {
    fn shrink_candidates(&self) -> Vec<Self> {
        match *self {
            0 => vec![],
            1 => vec![],
            n => vec![1, n / 2, n - 1],
        }
    }
}

/// Generate a sorted `Vec<i64>` with length drawn from `len_range` and
/// values from `val_range`.
pub fn sorted_vec(
    rng: &mut Xoshiro256,
    len_range: std::ops::Range<usize>,
    val_range: std::ops::Range<i64>,
) -> Vec<i64> {
    let n = if len_range.is_empty() {
        len_range.start
    } else {
        rng.range(len_range.start, len_range.end)
    };
    let span = (val_range.end - val_range.start).max(1) as u64;
    let mut v: Vec<i64> = (0..n)
        .map(|_| val_range.start + rng.below(span) as i64)
        .collect();
    v.sort_unstable();
    v
}

/// A counting wrapper over the system allocator for assertion-backed
/// peak-memory tests. Register it as the test binary's global
/// allocator and bracket the code under test with
/// [`CountingAlloc::reset_peak`] / [`CountingAlloc::peak`]:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: mergeflow::testutil::CountingAlloc =
///     mergeflow::testutil::CountingAlloc;
/// ```
///
/// Accounting is *realloc-delta* honest: a `realloc` charges only the
/// size difference, matching how large `Vec` growth behaves on real
/// allocators (an `mremap` does not transiently hold both copies), so
/// a grow-in-place concatenation ([`crate::mergepath::concat_for_inplace`])
/// is measured at its true cost instead of an apparent 2× spike.
/// Counters are process-global atomics: peak assertions should run in
/// their own integration-test binary (one `#[global_allocator]` per
/// binary, one test per run for a clean high-water mark).
pub struct CountingAlloc;

use std::alloc::{GlobalAlloc, Layout, System};

static ALLOC_CUR: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
static ALLOC_PEAK: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

impl CountingAlloc {
    fn add(n: usize) {
        use std::sync::atomic::Ordering;
        let now = ALLOC_CUR.fetch_add(n, Ordering::Relaxed) + n;
        ALLOC_PEAK.fetch_max(now, Ordering::Relaxed);
    }

    fn sub(n: usize) {
        ALLOC_CUR.fetch_sub(n, std::sync::atomic::Ordering::Relaxed);
    }

    /// Bytes currently outstanding.
    pub fn current() -> usize {
        ALLOC_CUR.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// High-water mark since the last [`CountingAlloc::reset_peak`].
    pub fn peak() -> usize {
        ALLOC_PEAK.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Reset the high-water mark to the current outstanding figure.
    pub fn reset_peak() {
        use std::sync::atomic::Ordering;
        ALLOC_PEAK.store(ALLOC_CUR.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

// SAFETY: delegates every operation to `std::alloc::System` verbatim;
// the counters are side effects only and never affect the returned
// pointers or layouts.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            Self::add(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            Self::add(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        Self::sub(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            // Delta accounting: only the size change is charged, so a
            // large buffer growing in place (or via mremap) is not
            // misread as a transient second copy.
            if new_size >= layout.size() {
                Self::add(new_size - layout.size());
            } else {
                Self::sub(layout.size() - new_size);
            }
        }
        p
    }
}

/// Deterministic, thread-safe fault-injection points.
///
/// A fail point is a named counter armed by a test
/// ([`FailPoint::arm`]) and checked by production code at a hazard
/// site ([`FailPoint::hit`]). The Nth check of an armed point (1-based)
/// returns `true` exactly once, then the point disarms itself — the
/// "fail once at N" contract crash-safety tests need to stop a
/// multi-step protocol at a precise step (mid-spill, pre-delete,
/// between manifest commit and input reclamation) and assert recovery.
///
/// The un-armed fast path is one relaxed atomic load, so hit sites are
/// free in production. State is process-global: concurrent tests must
/// use distinct point names (the store/server suites embed the test
/// name).
pub struct FailPoint;
static FAILPOINTS_ARMED: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);
static FAILPOINTS: std::sync::Mutex<Option<std::collections::HashMap<String, u64>>> =
    std::sync::Mutex::new(None);

impl FailPoint {
    /// Arm `name` to fire on its `at`-th [`FailPoint::hit`] (1-based).
    /// Re-arming an already-armed point resets its countdown.
    pub fn arm(name: &str, at: u64) {
        use std::sync::atomic::Ordering;
        let mut map = FAILPOINTS.lock().unwrap();
        let map = map.get_or_insert_with(Default::default);
        if map.insert(name.to_string(), at.max(1)).is_none() {
            FAILPOINTS_ARMED.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Check (and advance) the point. Returns `true` exactly once: on
    /// the armed Nth call, after which the point is disarmed.
    pub fn hit(name: &str) -> bool {
        use std::sync::atomic::Ordering;
        if FAILPOINTS_ARMED.load(Ordering::Relaxed) == 0 {
            return false; // fast path: nothing armed anywhere
        }
        let mut guard = FAILPOINTS.lock().unwrap();
        let Some(map) = guard.as_mut() else { return false };
        let Some(remaining) = map.get_mut(name) else { return false };
        *remaining -= 1;
        if *remaining == 0 {
            map.remove(name);
            FAILPOINTS_ARMED.fetch_sub(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Disarm `name` (no-op when not armed).
    pub fn clear(name: &str) {
        use std::sync::atomic::Ordering;
        let mut guard = FAILPOINTS.lock().unwrap();
        if let Some(map) = guard.as_mut() {
            if map.remove(name).is_some() {
                FAILPOINTS_ARMED.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Whether `name` is currently armed (not yet fired or cleared).
    pub fn is_armed(name: &str) -> bool {
        FAILPOINTS
            .lock()
            .unwrap()
            .as_ref()
            .is_some_and(|m| m.contains_key(name))
    }
}

/// Generate an arbitrary (unsorted) `Vec<i64>`.
pub fn any_vec(
    rng: &mut Xoshiro256,
    len_range: std::ops::Range<usize>,
    val_range: std::ops::Range<i64>,
) -> Vec<i64> {
    let n = if len_range.is_empty() {
        len_range.start
    } else {
        rng.range(len_range.start, len_range.end)
    };
    let span = (val_range.end - val_range.start).max(1) as u64;
    (0..n)
        .map(|_| val_range.start + rng.below(span) as i64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Prop::new(1).cases(50).run(
            |rng| sorted_vec(rng, 0..20, -5..5),
            |v| v.windows(2).all(|w| w[0] <= w[1]),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        Prop::new(2).cases(100).run(
            |rng| any_vec(rng, 0..50, -100..100),
            // False whenever the vec contains a negative number.
            |v| v.iter().all(|&x| x >= 0),
        );
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let v: Vec<i64> = (0..10).collect();
        for c in v.shrink_candidates() {
            assert!(c.len() < v.len() || c.iter().sum::<i64>() < v.iter().sum::<i64>());
        }
        assert!(Vec::<i64>::new().shrink_candidates().is_empty());
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // The minimal failing input for "no element equals 7" should be
        // a short vector; verify the shrinker reduces length.
        let failing = vec![3i64, 9, 7, 2, 8, 7, 1];
        let minimal = shrink_loop(failing, &|v: &Vec<i64>| !v.contains(&7));
        assert!(minimal.len() <= 2, "shrunk to {minimal:?}");
        assert!(minimal.contains(&7));
    }

    #[test]
    fn shrink_run_sets_reduces() {
        let runs: Vec<Vec<i64>> = vec![vec![1, 2], vec![3, 4, 5], vec![]];
        let cands = runs.shrink_candidates();
        assert!(!cands.is_empty());
        for c in &cands {
            let cells: usize = c.iter().map(|r| r.len()).sum();
            let orig: usize = runs.iter().map(|r| r.len()).sum();
            let sum: i64 = c.iter().flatten().sum();
            let orig_sum: i64 = runs.iter().flatten().sum();
            assert!(
                c.len() < runs.len() || cells < orig || sum < orig_sum,
                "candidate {c:?} is not smaller"
            );
        }
        assert!(Vec::<Vec<i64>>::new().shrink_candidates().is_empty());
    }

    #[test]
    fn failpoint_fires_once_at_n() {
        assert!(!FailPoint::hit("testutil.unit.never-armed"));
        FailPoint::arm("testutil.unit.third", 3);
        assert!(FailPoint::is_armed("testutil.unit.third"));
        assert!(!FailPoint::hit("testutil.unit.third"));
        assert!(!FailPoint::hit("testutil.unit.third"));
        assert!(FailPoint::hit("testutil.unit.third"), "fires on the 3rd hit");
        assert!(!FailPoint::hit("testutil.unit.third"), "fires exactly once");
        assert!(!FailPoint::is_armed("testutil.unit.third"));
    }

    #[test]
    fn failpoint_clear_and_rearm() {
        FailPoint::arm("testutil.unit.cleared", 1);
        FailPoint::clear("testutil.unit.cleared");
        assert!(!FailPoint::hit("testutil.unit.cleared"));
        // Re-arming resets the countdown.
        FailPoint::arm("testutil.unit.rearm", 5);
        assert!(!FailPoint::hit("testutil.unit.rearm"));
        FailPoint::arm("testutil.unit.rearm", 1);
        assert!(FailPoint::hit("testutil.unit.rearm"));
    }

    #[test]
    fn generators_respect_ranges() {
        let mut rng = Xoshiro256::seeded(3);
        for _ in 0..50 {
            let v = sorted_vec(&mut rng, 5..10, -3..3);
            assert!((5..10).contains(&v.len()));
            assert!(v.iter().all(|&x| (-3..3).contains(&x)));
            assert!(v.windows(2).all(|w| w[0] <= w[1]));
        }
    }
}
