//! Lightweight metrics: counters, wall-clock timers and a streaming
//! histogram with quantile queries. Used by the coordinator's stats
//! endpoint and by the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic event counter (thread-safe).
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// New counter at zero.
    pub const fn new() -> Self {
        Self { value: AtomicU64::new(0) }
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Up/down gauge with a high-water mark (thread-safe).
///
/// Tracks a current value (`add`/`sub`) and the peak it ever reached.
/// Used for resident-byte accounting: `add` on ingest/dispatch, `sub`
/// on reclaim/completion, `peak` answers "what did this cost at worst".
///
/// `sub` saturates at zero rather than wrapping: concurrent add/sub
/// interleavings can transiently observe more released than acquired,
/// and a monitoring gauge must degrade gracefully, not panic.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
    peak: AtomicU64,
}

impl Gauge {
    /// New gauge at zero.
    pub const fn new() -> Self {
        Self {
            value: AtomicU64::new(0),
            peak: AtomicU64::new(0),
        }
    }

    /// Raise the current value by `n` and fold it into the peak.
    pub fn add(&self, n: u64) {
        let now = self.value.fetch_add(n, Ordering::Relaxed).saturating_add(n);
        self.fold_peak(now);
    }

    /// Overwrite the current value (sampled gauges: queue depth, ages)
    /// and fold it into the peak.
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
        self.fold_peak(v);
    }

    /// Monotone peak fold. A plain `fetch_max` is insufficient on
    /// targets that polyfill it with load+CAS without a retry bound,
    /// and two concurrent `add`s can each observe a stale peak between
    /// their own `fetch_add` and the max update; an explicit CAS loop
    /// that only ever raises the peak makes the high-water mark exact
    /// for every interleaving of concurrent `add`/`set` calls.
    fn fold_peak(&self, candidate: u64) {
        let mut seen = self.peak.load(Ordering::Relaxed);
        while candidate > seen {
            match self.peak.compare_exchange_weak(
                seen,
                candidate,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => seen = now,
            }
        }
    }

    /// Lower the current value by `n` (saturating at zero).
    pub fn sub(&self, n: u64) {
        let mut cur = self.value.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.value.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Highest value ever observed by `add`.
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }
}

/// Scope timer: measures from construction to `stop()` (or drop).
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed time so far.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed nanoseconds (saturating at u64::MAX).
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// Fixed-bucket log₂ histogram of `u64` samples (e.g. latency in ns).
///
/// Bucket `i` holds samples in `[2^i, 2^(i+1))`; quantiles are answered
/// to bucket resolution (≤ 2x relative error), which is plenty for the
/// p50/p95/p99 service metrics.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// New histogram with 64 log₂ buckets.
    pub fn new() -> Self {
        Self {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        let b = (64 - v.max(1).leading_zeros() - 1) as usize;
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean of recorded samples (0 if empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Maximum recorded sample.
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile `q ∈ [0, 1]` (upper bound of the bucket
    /// containing the q-th sample; 0 if empty).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i >= 63 { u64::MAX } else { (1u64 << (i + 1)) - 1 };
            }
        }
        self.max()
    }
}

/// Format a nanosecond duration human-readably.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Throughput in human units (elements/second).
pub fn fmt_throughput(elems: u64, ns: u64) -> String {
    if ns == 0 {
        return "∞".into();
    }
    let eps = elems as f64 / (ns as f64 / 1e9);
    if eps >= 1e9 {
        format!("{:.2} Ge/s", eps / 1e9)
    } else if eps >= 1e6 {
        format!("{:.2} Me/s", eps / 1e6)
    } else if eps >= 1e3 {
        format!("{:.2} Ke/s", eps / 1e3)
    } else {
        format!("{eps:.1} e/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basic() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_tracks_current_and_peak() {
        let g = Gauge::new();
        g.add(100);
        g.add(50);
        g.sub(120);
        assert_eq!(g.get(), 30);
        assert_eq!(g.peak(), 150);
        // Saturating sub: over-release clamps at zero, peak untouched.
        g.sub(1000);
        assert_eq!(g.get(), 0);
        assert_eq!(g.peak(), 150);
        g.add(10);
        assert_eq!(g.get(), 10);
        assert_eq!(g.peak(), 150, "peak is a high-water mark");
    }

    #[test]
    fn gauge_set_overwrites_and_folds_peak() {
        let g = Gauge::new();
        g.set(40);
        g.set(7);
        assert_eq!(g.get(), 7);
        assert_eq!(g.peak(), 40);
        g.add(100);
        assert_eq!(g.get(), 107);
        assert_eq!(g.peak(), 107);
    }

    /// Concurrent `add`s must never lose the true high-water mark: with
    /// every thread adding before any subtracts, the peak must be at
    /// least the full sum regardless of how the peak folds interleave.
    #[test]
    fn gauge_peak_exact_under_concurrent_adds() {
        use std::sync::Arc;
        const THREADS: u64 = 8;
        const PER_THREAD: u64 = 1000;
        let g = Arc::new(Gauge::new());
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let g = Arc::clone(&g);
                std::thread::spawn(move || {
                    for _ in 0..PER_THREAD {
                        g.add(1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.get(), THREADS * PER_THREAD);
        assert_eq!(g.peak(), THREADS * PER_THREAD, "no add may be missed by the peak");
        // And a mixed add/sub phase never raises the peak spuriously.
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let g = Arc::clone(&g);
                std::thread::spawn(move || {
                    for _ in 0..PER_THREAD {
                        g.add(3);
                        g.sub(3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.get(), THREADS * PER_THREAD);
        assert!(g.peak() <= THREADS * (PER_THREAD + 3), "peak bounded by max possible residency");
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 of 1..=1000 is ~500; bucket upper bound gives ≤ 1023.
        assert!((256..=1023).contains(&p50), "p50={p50}");
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_zero_and_huge() {
        let h = Histogram::new();
        h.record(0); // clamped into bucket 0
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.50µs");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.00s");
        assert!(fmt_throughput(1_000_000, 1_000_000_000).contains("Me/s"));
        assert_eq!(fmt_throughput(1, 0), "∞");
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ns() >= 1_000_000);
    }
}
