//! Persistent run store: crash-safety under injected kill points,
//! scheduler/flush end-to-end behavior, and the coordinator + wire
//! integration of `Spill`/`Flush`/`STORE_STATS`.
//!
//! The crash-recovery property pinned here: after a kill at *any*
//! injected point (mid-spill, mid-manifest-write, between compaction
//! install and input delete), reopening the store yields exactly the
//! records of the last complete manifest generation — bit-identical to
//! the oracle, no loss, no duplicates — and every orphaned file is
//! reclaimed.
//!
//! `FailPoint`s are process-global, so every test in this file takes
//! the `serial()` guard: a concurrent test's spill must never consume
//! another test's armed kill.

use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig, ServerConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::server::{serve, Client};
use mergeflow::store::scheduler::run_pass;
use mergeflow::store::{
    manifest_name, run_file_name, LevelScheduler, RunStore, StoreBridge, StoreConfig,
    StorePolicy,
};
use mergeflow::testutil::FailPoint;
use mergeflow::Error;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Serialize all tests in this binary (see module docs).
fn serial() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

struct TempDir(PathBuf);

impl TempDir {
    fn new(name: &str) -> Self {
        let dir = std::env::temp_dir()
            .join(format!("mergeflow-store-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Self(dir)
    }

    /// Tiny blocks (64 B) so even small runs span many blocks.
    fn cfg(&self) -> StoreConfig {
        StoreConfig {
            dir: self.0.to_string_lossy().into_owned(),
            policy: StorePolicy::Tiered,
            level0_max_runs: 4,
            level_fanout: 4,
            block_bytes: 64,
            compact_backoff_ms: 5,
        }
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn base_config() -> MergeflowConfig {
    MergeflowConfig {
        workers: 2,
        threads_per_job: 2,
        queue_capacity: 256,
        max_batch: 8,
        batch_timeout_us: 100,
        backend: Backend::Native,
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Auto,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    }
}

/// All live records, read back through the chunked readers, flattened
/// and key-sorted — the store-side image to compare against an oracle.
fn contents(store: &RunStore<i32>) -> Vec<i32> {
    let (_, runs) = store.snapshot();
    let mut all = Vec::new();
    for meta in &runs {
        let mut rd = store.reader(meta).expect("open reader");
        while let Some(block) = rd.next_block().expect("read block") {
            all.extend(block);
        }
    }
    all.sort_unstable();
    all
}

fn run_files(dir: &PathBuf) -> Vec<String> {
    let mut v: Vec<String> = std::fs::read_dir(dir)
        .expect("read store dir")
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("run-"))
        .collect();
    v.sort();
    v
}

fn sorted_run(lo: i32, n: i32) -> Vec<i32> {
    (lo..lo + n).collect()
}

fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------
// Crash safety: every injected kill point must recover to the last
// complete generation, bit-identical, with orphans reclaimed.
// ---------------------------------------------------------------------

#[test]
fn kill_mid_spill_recovers_without_the_orphan() {
    let _g = serial();
    let t = TempDir::new("kill-mid-spill");
    let store = RunStore::<i32>::open(&t.cfg()).unwrap();
    let survivor = sorted_run(0, 300);
    store.spill(&survivor).unwrap();

    // The second spill dies after writing its run file, before the
    // manifest commit that would make it live.
    FailPoint::arm("store.spill.precommit", 1);
    let verdict = store.spill(&sorted_run(1_000, 300)).unwrap_err();
    assert!(matches!(verdict, Error::Service(_)), "crash surfaces as Service: {verdict}");
    assert!(!FailPoint::is_armed("store.spill.precommit"));
    assert_eq!(store.generation(), 1, "the torn spill never committed");
    assert_eq!(run_files(&t.0).len(), 2, "the orphan run file is on disk");
    drop(store);

    let store = RunStore::<i32>::open(&t.cfg()).unwrap();
    assert_eq!((store.generation(), store.run_count()), (1, 1));
    assert_eq!(contents(&store), survivor, "recovery is bit-identical to gen 1");
    assert_eq!(run_files(&t.0).len(), 1, "recovery reclaimed the orphan");
    store.verify().unwrap();
}

#[test]
fn torn_manifest_falls_back_one_generation() {
    let _g = serial();
    let t = TempDir::new("torn-manifest");
    let store = RunStore::<i32>::open(&t.cfg()).unwrap();
    let survivor = sorted_run(0, 500);
    store.spill(&survivor).unwrap();

    // The next spill is killed mid-manifest-write: a truncated
    // generation-2 image lands under the *final* manifest name.
    FailPoint::arm("store.manifest.torn", 1);
    store.spill(&sorted_run(2_000, 500)).unwrap_err();
    assert!(t.0.join(manifest_name(2)).exists(), "the torn image exists");
    drop(store);

    let store = RunStore::<i32>::open(&t.cfg()).unwrap();
    assert_eq!(store.generation(), 1, "recovery fell back past the torn image");
    assert_eq!(contents(&store), survivor, "gen 1 is intact, bit for bit");
    assert!(!t.0.join(manifest_name(2)).exists(), "the torn image was deleted");
    assert_eq!(run_files(&t.0).len(), 1, "the uncommitted run was deleted");

    // The store is fully usable after the fallback: the next commit
    // simply takes the next generation number.
    store.spill(&sorted_run(2_000, 500)).unwrap();
    assert_eq!((store.generation(), store.run_count()), (2, 2));
    store.verify().unwrap();
}

#[test]
fn kill_between_install_and_delete_reclaims_the_inputs() {
    let _g = serial();
    let t = TempDir::new("install-predelete");
    let store = RunStore::<i32>::open(&t.cfg()).unwrap();
    let svc = MergeService::<i32>::start(base_config()).unwrap();
    let mut oracle = Vec::new();
    for i in 0..4 {
        let run = sorted_run(i * 100, 250); // overlapping key ranges
        oracle.extend_from_slice(&run);
        store.spill(&run).unwrap();
    }
    oracle.sort_unstable();

    // One full compaction pass (4 L0 runs >= level0_max_runs) that is
    // killed after installing the merged output, before deleting the
    // four inputs.
    FailPoint::arm("store.compact.predelete", 1);
    let verdict = run_pass(&store, &svc, svc.stats()).unwrap_err();
    assert!(matches!(verdict, Error::Service(_)), "{verdict}");
    assert_eq!(
        run_files(&t.0).len(),
        5,
        "output installed, inputs not yet deleted — the dangerous window"
    );
    drop(store);

    // Recovery: the new generation is authoritative; the four input
    // files are orphans. No loss, no duplicates.
    let store = RunStore::<i32>::open(&t.cfg()).unwrap();
    assert_eq!(store.run_count(), 1, "only the merged output is live");
    assert_eq!(run_files(&t.0).len(), 1, "input orphans reclaimed");
    assert_eq!(contents(&store), oracle, "merged output is bit-identical");
    store.verify().unwrap();
    svc.shutdown();
}

#[test]
fn verify_detects_a_flipped_bit() {
    let _g = serial();
    let t = TempDir::new("verify-corruption");
    let store = RunStore::<i32>::open(&t.cfg()).unwrap();
    let meta = store.spill(&sorted_run(0, 400)).unwrap();
    store.verify().unwrap();

    // Flip one byte inside the first data block's payload (past the
    // 16-byte file header and the 8-byte block header).
    let path = t.0.join(run_file_name(meta.file_id));
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[16 + 8] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let verdict = store.verify().unwrap_err();
    assert!(
        verdict.to_string().contains("crc"),
        "the block CRC catches the flip: {verdict}"
    );
}

// ---------------------------------------------------------------------
// Coordinator integration: Spill/Flush jobs, stats, scheduler.
// ---------------------------------------------------------------------

#[test]
fn spill_and_flush_jobs_compact_the_store_to_policy() {
    let _g = serial();
    let t = TempDir::new("svc-flush");
    let store = Arc::new(RunStore::<i32>::open(&t.cfg()).unwrap());
    let svc = MergeService::<i32>::start(base_config()).unwrap();

    // Spill without a store attached fails fast and is ledgered.
    assert!(svc.submit(JobKind::Spill { run: vec![1, 2, 3] }).is_err());
    svc.attach_store(Arc::new(StoreBridge::new(Arc::clone(&store), svc.stats_arc())))
        .unwrap();
    assert!(svc.has_store());

    // Unsorted and empty spills are refused at submit.
    assert!(matches!(
        svc.submit(JobKind::Spill { run: vec![5, 3, 4] }).unwrap_err(),
        Error::InvalidInput(_)
    ));
    assert!(matches!(
        svc.submit(JobKind::Spill { run: vec![] }).unwrap_err(),
        Error::InvalidInput(_)
    ));

    // Eight spill jobs through the pool; the result echoes the run.
    let mut oracle = Vec::new();
    for i in 0..8 {
        let run = sorted_run(i * 64, 256);
        oracle.extend_from_slice(&run);
        let r = svc.submit_blocking(JobKind::Spill { run: run.clone() }).unwrap();
        assert_eq!(r.backend, "store-spill");
        assert_eq!(r.output, run, "spill echoes its input");
    }
    oracle.sort_unstable();
    wait_for("all spills durable", || store.run_count() == 8);

    // A synchronous Flush drives compaction until within policy:
    // tiered with 8 >= level0_max_runs merges all eight into one L1 run.
    let r = svc.submit_blocking(JobKind::Flush).unwrap();
    assert_eq!(r.backend, "store-flush");
    assert!(r.output.is_empty(), "flush returns no records");
    assert_eq!(store.run_count(), 1, "eight L0 runs became one L1 run");
    assert_eq!(contents(&store), oracle, "compacted store is bit-identical");

    let stats = svc.stats();
    assert_eq!(stats.store_spills.get(), 8);
    assert_eq!(stats.store_flushes.get(), 1);
    assert!(stats.store_compactions.get() >= 1);
    assert_eq!(stats.store_runs.get(), 1);
    assert_eq!(stats.rejected.get(), 3, "the three precondition refusals were counted");
    assert_eq!(
        stats.submitted.get(),
        stats.completed.get(),
        "every admitted spill/flush (and the flush's internal compaction) completed"
    );
    let text = svc.store_stats_text().expect("store stats text");
    assert!(text.contains("generation="), "{text}");
    let snapshot = stats.snapshot();
    assert!(snapshot.contains("spills=8"), "{snapshot}");
    svc.shutdown();
}

#[test]
fn background_scheduler_compacts_while_spills_arrive() {
    let _g = serial();
    let t = TempDir::new("bg-scheduler");
    let store = Arc::new(RunStore::<i32>::open(&t.cfg()).unwrap());
    let svc = Arc::new(MergeService::<i32>::start(base_config()).unwrap());
    let scheduler = LevelScheduler::start(Arc::clone(&store), Arc::clone(&svc));

    let mut oracle = Vec::new();
    for i in 0..10 {
        let run = sorted_run(i * 37, 200);
        oracle.extend_from_slice(&run);
        store.spill(&run).unwrap();
    }
    oracle.sort_unstable();

    // The scheduler must converge the backlog below the L0 threshold
    // without any explicit flush.
    wait_for("scheduler converges L0", || {
        store.levels().first().map_or(true, |l0| l0.len() < 4)
    });
    scheduler.stop();
    assert!(svc.stats().scheduler_passes.get() >= 1, "at least one pass ran");
    assert_eq!(contents(&store), oracle, "no records lost or duplicated");
    store.verify().unwrap();
    svc.shutdown();
}

// ---------------------------------------------------------------------
// Wire integration: FLUSH (spill + drain) and STORE_STATS verbs.
// ---------------------------------------------------------------------

#[test]
fn spill_flush_and_store_stats_over_the_wire() {
    let _g = serial();
    let t = TempDir::new("wire");
    let store = Arc::new(RunStore::<i32>::open(&t.cfg()).unwrap());
    let svc = Arc::new(MergeService::<i32>::start(base_config()).unwrap());
    svc.attach_store(Arc::new(StoreBridge::new(Arc::clone(&store), svc.stats_arc())))
        .unwrap();
    let scfg = ServerConfig { listen: "127.0.0.1:0".into(), lease_ms: 0, ..Default::default() };
    let server = serve(Arc::clone(&svc), scfg).unwrap();
    let mut client = Client::<i32>::connect(server.local_addr(), "store-user").unwrap();

    let mut oracle = Vec::new();
    for i in 0..5 {
        let run = sorted_run(i * 50, 120);
        oracle.extend_from_slice(&run);
        let (backend, echoed) = client.spill(&run).unwrap();
        assert_eq!(backend, "store-spill");
        assert_eq!(echoed, run);
    }
    oracle.sort_unstable();
    assert!(
        matches!(client.spill(&[3, 1, 2]).unwrap_err(), Error::InvalidInput(_)),
        "unsorted spill is a typed invalid-input on the wire"
    );

    // An empty FLUSH payload means drain: 5 >= level0_max_runs merges.
    let (backend, out) = client.flush().unwrap();
    assert_eq!(backend, "store-flush");
    assert!(out.is_empty());
    assert_eq!(store.run_count(), 1);
    assert_eq!(contents(&store), oracle, "wire-fed store is bit-identical");

    let text = client.store_stats().unwrap();
    assert!(text.contains("generation="), "{text}");
    assert!(text.contains("L1:"), "{text}");
    server.shutdown();
}

#[test]
fn store_verbs_without_a_store_get_typed_refusals() {
    let _g = serial();
    let svc = Arc::new(MergeService::<i32>::start(base_config()).unwrap());
    let scfg = ServerConfig { listen: "127.0.0.1:0".into(), lease_ms: 0, ..Default::default() };
    let server = serve(Arc::clone(&svc), scfg).unwrap();
    let mut client = Client::<i32>::connect(server.local_addr(), "storeless").unwrap();
    let verdict = client.spill(&[1, 2, 3]).unwrap_err();
    assert!(
        verdict.to_string().contains("no store"),
        "spill names the missing store: {verdict}"
    );
    let verdict = client.store_stats().unwrap_err();
    assert!(
        verdict.to_string().contains("no store"),
        "store_stats names the missing store: {verdict}"
    );
    // The connection keeps serving after both refusals.
    client.ping().unwrap();
    server.shutdown();
}
