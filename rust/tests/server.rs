//! Wire-server conformance and robustness, end to end over real
//! sockets: every verb must be byte-identical to the in-process
//! one-shot oracle for scalar and `(key, payload)` records; dead
//! clients (clean drop, half-written frame, lease silence) must be
//! reaped with `resident_bytes` drained back to zero; malformed frames
//! must get typed error replies and never kill the server; and
//! per-tenant quotas must answer fail-fast `BUSY` while well-behaved
//! tenants keep streaming.

use mergeflow::bench::workload::{
    gen_sorted_pair, gen_sorted_runs, gen_unsorted, WorkloadKind,
};
use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig, ServerConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::server::frame::{
    self, err, tag, Cursor, FrameError, ReadOpts, PROTOCOL_VERSION,
};
use mergeflow::server::{is_busy, serve, Client, ServerHandle, WireRecord};
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn base_config() -> MergeflowConfig {
    MergeflowConfig {
        workers: 2,
        threads_per_job: 2,
        queue_capacity: 256,
        max_batch: 8,
        batch_timeout_us: 100,
        backend: Backend::Native,
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Auto,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    }
}

/// Loopback TCP on a kernel-assigned port, lease disabled (the lease
/// test opts in explicitly so slow CI cannot reap a healthy client).
fn loopback() -> ServerConfig {
    ServerConfig { listen: "127.0.0.1:0".into(), lease_ms: 0, ..Default::default() }
}

fn start<R: WireRecord>(
    cfg: MergeflowConfig,
    scfg: ServerConfig,
) -> (Arc<MergeService<R>>, ServerHandle) {
    let svc = Arc::new(MergeService::start(cfg).expect("service start"));
    let server = serve(Arc::clone(&svc), scfg).expect("server start");
    (svc, server)
}

fn sorted_oracle(runs: &[Vec<i32>]) -> Vec<i32> {
    let mut v: Vec<i32> = runs.iter().flatten().copied().collect();
    v.sort_unstable();
    v
}

fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Dial raw TCP and complete the `HELLO` handshake by hand — the
/// fault-injection path that lets a test write arbitrary bytes where
/// [`Client`] would only ever write well-formed frames.
fn raw_hello(addr: &str, tenant: &str) -> TcpStream {
    let mut s = TcpStream::connect(addr).expect("raw dial");
    let mut hello = Vec::new();
    frame::put_varint(&mut hello, PROTOCOL_VERSION);
    frame::put_varint(&mut hello, u64::from(<i32 as WireRecord>::WIRE_ID));
    hello.extend_from_slice(tenant.as_bytes());
    frame::write_frame(&mut s, tag::HELLO, &hello).unwrap();
    let (t, _) = read_reply(&mut s);
    assert_eq!(t, tag::HELLO_OK);
    s
}

fn read_reply(s: &mut TcpStream) -> (u8, Vec<u8>) {
    frame::read_frame(s, 1 << 20, &ReadOpts::default()).expect("reply frame")
}

// ---------------------------------------------------------------------
// Conformance: every verb × every workload kind × oracle.
// ---------------------------------------------------------------------

#[test]
fn every_verb_matches_the_in_process_oracle() {
    let (svc, server) = start::<i32>(base_config(), loopback());
    let mut client = Client::<i32>::connect(server.local_addr(), "conformance").unwrap();
    client.ping().unwrap();

    for (w, kind) in WorkloadKind::all().into_iter().enumerate() {
        let seed = 0xC0DE + w as u64;

        // MERGE against the same service's in-process submission.
        let (a, b) = gen_sorted_pair(kind, 3_000, 2_000, seed);
        let oracle = svc
            .submit_blocking(JobKind::Merge { a: a.clone(), b: b.clone() })
            .unwrap();
        let (backend, out) = client.merge(&a, &b).unwrap();
        assert_eq!(out, oracle.output, "{kind:?} merge output");
        assert_eq!(backend, oracle.backend, "{kind:?} merge backend");

        // SORT.
        let data = gen_unsorted(4_000, seed);
        let oracle = svc
            .submit_blocking(JobKind::Sort { data: data.clone() })
            .unwrap();
        let (backend, out) = client.sort(&data).unwrap();
        assert_eq!(out, oracle.output, "{kind:?} sort output");
        assert_eq!(backend, oracle.backend, "{kind:?} sort backend");

        // COMPACT.
        let runs = gen_sorted_runs(kind, 5, 800, seed);
        let oracle = svc
            .submit_blocking(JobKind::Compact { runs: runs.clone() })
            .unwrap();
        let (backend, out) = client.compact(&runs).unwrap();
        assert_eq!(out, oracle.output, "{kind:?} compact output");
        assert_eq!(backend, oracle.backend, "{kind:?} compact backend");

        // OPEN / FEED / SEAL_RUN / SEAL: the chunked streaming protocol
        // must reproduce the one-shot output bit for bit.
        let sid = client.open(runs.len()).unwrap();
        for (r, run) in runs.iter().enumerate() {
            for chunk in run.chunks(257) {
                client.feed(sid, r, chunk).unwrap();
            }
            client.seal_run(sid, r).unwrap();
        }
        let (_, streamed) = client.seal(sid).unwrap();
        assert_eq!(streamed, oracle.output, "{kind:?} streamed session output");
    }

    let stats = client.stats().unwrap();
    assert!(stats.contains("jobs:"), "{stats}");
    assert!(stats.contains("tenant conformance:"), "{stats}");
    server.shutdown();
}

#[test]
fn hello_refuses_a_mismatched_record_type() {
    let (_svc, server) = start::<i32>(base_config(), loopback());
    let verdict = Client::<u64>::connect(server.local_addr(), "imposter").unwrap_err();
    assert!(
        verdict.to_string().contains("code 5"),
        "expected the UNSUPPORTED verdict, got: {verdict}"
    );
    // The refusal is per-connection: a properly-typed client is served.
    let mut ok = Client::<i32>::connect(server.local_addr(), "fine").unwrap();
    ok.ping().unwrap();
    server.shutdown();
}

#[cfg(unix)]
#[test]
fn typed_records_stream_over_a_unix_socket() {
    let path = std::env::temp_dir()
        .join(format!("mergeflow-wire-{}.sock", std::process::id()));
    let scfg = ServerConfig {
        listen: format!("unix:{}", path.display()),
        lease_ms: 0,
        ..Default::default()
    };
    let (svc, server) = start::<(u64, u64)>(base_config(), scfg);
    assert!(server.local_addr().starts_with("unix:"), "{}", server.local_addr());
    let mut client =
        Client::<(u64, u64)>::connect(server.local_addr(), "typed").unwrap();

    let k = 4usize;
    let run_len = 1_200usize;
    let runs: Vec<Vec<(u64, u64)>> = (0..k)
        .map(|run| {
            let (keys, _) =
                gen_sorted_pair(WorkloadKind::Skewed, run_len, 1, 40 + run as u64);
            keys.into_iter()
                .enumerate()
                .map(|(off, key)| {
                    let key = (i64::from(key) - i64::from(i32::MIN)) as u64;
                    (key, ((run as u64) << 32) | off as u64)
                })
                .collect()
        })
        .collect();
    // Stable oracle: flatten in run order, stable-sort by key — ties
    // must come out in run-index-then-offset order on the wire too.
    let mut expected: Vec<(u64, u64)> = runs.iter().flatten().copied().collect();
    expected.sort_by_key(|r| r.0);

    let oracle = svc
        .submit_blocking(JobKind::Compact { runs: runs.clone() })
        .unwrap();
    let (_, compacted) = client.compact(&runs).unwrap();
    assert_eq!(compacted, oracle.output, "typed wire compaction vs oracle");
    assert_eq!(compacted, expected, "typed wire compaction must keep stable ties");

    // The session verbs carry typed records too.
    let sid = client.open(k).unwrap();
    for (r, run) in runs.iter().enumerate() {
        for chunk in run.chunks(333) {
            client.feed(sid, r, chunk).unwrap();
        }
        client.seal_run(sid, r).unwrap();
    }
    let (_, streamed) = client.seal(sid).unwrap();
    assert_eq!(streamed, expected, "typed streamed session output");
    server.shutdown();
}

#[test]
fn interleaved_sessions_share_one_connection() {
    let (_svc, server) = start::<i32>(base_config(), loopback());
    let mut client = Client::<i32>::connect(server.local_addr(), "weaver").unwrap();
    let runs_a = gen_sorted_runs(WorkloadKind::Skewed, 2, 1_500, 11);
    let runs_b = gen_sorted_runs(WorkloadKind::Interleaved, 3, 900, 12);
    let sa = client.open(runs_a.len()).unwrap();
    let sb = client.open(runs_b.len()).unwrap();
    assert_ne!(sa, sb);

    let chunks = |runs: &[Vec<i32>]| -> Vec<(usize, Vec<i32>)> {
        let mut v = Vec::new();
        for (r, run) in runs.iter().enumerate() {
            for chunk in run.chunks(301) {
                v.push((r, chunk.to_vec()));
            }
        }
        v
    };
    let qa = chunks(&runs_a);
    let qb = chunks(&runs_b);
    // Strictly alternating feeds between the two open sessions.
    let (mut ia, mut ib) = (0usize, 0usize);
    while ia < qa.len() || ib < qb.len() {
        if ia < qa.len() {
            let (r, chunk) = &qa[ia];
            client.feed(sa, *r, chunk).unwrap();
            ia += 1;
        }
        if ib < qb.len() {
            let (r, chunk) = &qb[ib];
            client.feed(sb, *r, chunk).unwrap();
            ib += 1;
        }
    }
    for r in 0..runs_a.len() {
        client.seal_run(sa, r).unwrap();
    }
    for r in 0..runs_b.len() {
        client.seal_run(sb, r).unwrap();
    }
    // Seal in the reverse order of opening: the map is id-addressed.
    let (_, out_b) = client.seal(sb).unwrap();
    let (_, out_a) = client.seal(sa).unwrap();
    assert_eq!(out_a, sorted_oracle(&runs_a), "session A interleaved");
    assert_eq!(out_b, sorted_oracle(&runs_b), "session B interleaved");
    server.shutdown();
}

// ---------------------------------------------------------------------
// Fault injection: dead clients must be reaped, not leak admission.
// ---------------------------------------------------------------------

#[test]
fn a_killed_connection_is_reaped_and_its_quota_drained() {
    use mergeflow::testutil::FailPoint;
    let (svc, server) = start::<i32>(base_config(), loopback());
    let mut victim = Client::<i32>::connect(server.local_addr(), "victim").unwrap();
    let sid = victim.open(2).unwrap();
    let (chunk, _) = gen_sorted_pair(WorkloadKind::Uniform, 1_000, 1, 77);
    victim.feed(sid, 0, &chunk).unwrap();
    assert!(svc.stats().resident_bytes.get() > 0, "ingest is resident");
    // Deterministic server-side kill: the handler drops the very next
    // frame it reads (modeling a crashed connection task at a frame
    // boundary) — replacing the old ad-hoc scope-drop ordering, which
    // raced the reaper against the client's TCP teardown. The point is
    // tenant-scoped, so concurrent tests cannot consume the kill.
    FailPoint::arm("server.conn.kill.victim", 1);
    assert!(victim.ping().is_err(), "the killed connection is dead");
    assert!(
        !FailPoint::is_armed("server.conn.kill.victim"),
        "the kill point fired exactly once"
    );
    wait_for("reap after connection kill", || svc.stats().sessions_reaped.get() >= 1);
    wait_for("resident bytes drained", || svc.stats().resident_bytes.get() == 0);
    let stats = svc.stats();
    assert_eq!(
        stats.submitted.get(),
        stats.completed.get() + stats.rejected.get(),
        "an abandoned session never enters the job ledger"
    );

    // The server keeps serving after the reap.
    let mut next = Client::<i32>::connect(server.local_addr(), "survivor").unwrap();
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 500, 500, 78);
    let (_, out) = next.merge(&a, &b).unwrap();
    assert_eq!(out.len(), 1_000);
    let text = next.stats().unwrap();
    assert!(text.contains("tenant victim:"), "{text}");
    server.shutdown();
}

#[test]
fn half_written_frame_gets_a_typed_error_and_the_session_reaped() {
    let (svc, server) = start::<i32>(base_config(), loopback());
    let mut s = raw_hello(server.local_addr(), "raw");

    // OPEN a 1-run session by hand.
    let mut p = Vec::new();
    frame::put_varint(&mut p, 1);
    frame::write_frame(&mut s, tag::OPEN, &p).unwrap();
    let (t, reply) = read_reply(&mut s);
    assert_eq!(t, tag::OPENED);
    let sid = Cursor::new(&reply).get_varint().unwrap();

    // One good FEED...
    let mut p = Vec::new();
    frame::put_varint(&mut p, sid);
    frame::put_varint(&mut p, 0);
    frame::put_records(&mut p, &[1i32, 2, 3]);
    frame::write_frame(&mut s, tag::FEED, &p).unwrap();
    let (t, _) = read_reply(&mut s);
    assert_eq!(t, tag::OK);

    // ...then a frame that declares 64 payload bytes, delivers 3, and
    // hangs up its write half mid-frame.
    let mut partial = vec![tag::FEED];
    frame::put_varint(&mut partial, 64);
    partial.extend_from_slice(&[9, 9, 9]);
    s.write_all(&partial).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();

    let (t, payload) = read_reply(&mut s);
    assert_eq!(t, tag::ERR, "typed error frame, not a silent hangup");
    assert_eq!(payload[0], err::PROTOCOL);
    assert!(
        matches!(
            frame::read_frame(&mut s, 1 << 20, &ReadOpts::default()),
            Err(FrameError::Closed) | Err(FrameError::Eof)
        ),
        "the connection closes after a desynchronized stream"
    );

    wait_for("reap after mid-frame hangup", || {
        svc.stats().sessions_reaped.get() >= 1
    });
    wait_for("resident bytes drained", || svc.stats().resident_bytes.get() == 0);
    let stats = svc.stats();
    assert_eq!(stats.submitted.get(), stats.completed.get() + stats.rejected.get());
    server.shutdown();
}

#[test]
fn lease_expiry_reaps_a_silent_client() {
    let scfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        lease_ms: 500,
        ..Default::default()
    };
    let (svc, server) = start::<i32>(base_config(), scfg);
    let mut client = Client::<i32>::connect(server.local_addr(), "sleepy").unwrap();
    let sid = client.open(1).unwrap();
    client.feed(sid, 0, &[1, 2, 3]).unwrap();

    // Heartbeats (any frame — PING is the idiom) hold the lease...
    for _ in 0..3 {
        std::thread::sleep(Duration::from_millis(100));
        client.ping().unwrap();
    }
    assert_eq!(svc.stats().sessions_reaped.get(), 0, "heartbeats hold the lease");

    // ...then silence past serve.lease_ms gets the connection reaped.
    wait_for("lease reap", || svc.stats().sessions_reaped.get() >= 1);
    wait_for("resident bytes drained", || svc.stats().resident_bytes.get() == 0);
    assert!(client.ping().is_err(), "the leased-out connection is dead");
    server.shutdown();
}

// ---------------------------------------------------------------------
// Decoder robustness: malformed frames get typed errors, never panics.
// ---------------------------------------------------------------------

#[test]
fn malformed_frame_corpus_gets_typed_errors_and_never_kills_the_server() {
    struct Case {
        name: &'static str,
        bytes: Vec<u8>,
        hangup: bool,
        code: u8,
        msg_contains: &'static str,
        closes: bool,
    }
    let frame_of = |t: u8, payload: &[u8]| {
        let mut v = Vec::new();
        frame::write_frame(&mut v, t, payload).unwrap();
        v
    };

    // A length varint that cannot terminate within u64.
    let mut overflow = vec![tag::MERGE];
    overflow.extend_from_slice(&[0xff; 11]);
    // A header declaring a terabyte payload (server cap is 64 MiB):
    // must be refused before any allocation or payload read.
    let mut oversized = vec![tag::FEED];
    frame::put_varint(&mut oversized, 1 << 40);
    // A FEED whose record count overruns the bytes actually present.
    let mut overrun = Vec::new();
    frame::put_varint(&mut overrun, 1); // session id
    frame::put_varint(&mut overrun, 0); // run
    frame::put_varint(&mut overrun, 1_000); // declares 1000 records...
    overrun.extend_from_slice(&[0, 0, 0, 0]); // ...delivers 4 bytes
    // A well-formed second HELLO after the handshake.
    let mut hello_again = Vec::new();
    frame::put_varint(&mut hello_again, PROTOCOL_VERSION);
    frame::put_varint(&mut hello_again, u64::from(<i32 as WireRecord>::WIRE_ID));

    let cases = vec![
        Case {
            name: "truncated header",
            bytes: vec![tag::MERGE],
            hangup: true,
            code: err::PROTOCOL,
            msg_contains: "mid-frame",
            closes: true,
        },
        Case {
            name: "length varint overflow",
            bytes: overflow,
            hangup: false,
            code: err::PROTOCOL,
            msg_contains: "varint",
            closes: true,
        },
        Case {
            name: "oversized declared payload",
            bytes: oversized,
            hangup: false,
            code: err::PROTOCOL,
            msg_contains: "serve.max_frame_bytes",
            closes: true,
        },
        Case {
            name: "unknown verb",
            bytes: frame_of(0x5f, &[]),
            hangup: false,
            code: err::UNKNOWN_VERB,
            msg_contains: "unknown verb",
            closes: false,
        },
        Case {
            name: "record count overruns payload",
            bytes: frame_of(tag::FEED, &overrun),
            hangup: false,
            code: err::PROTOCOL,
            msg_contains: "record count",
            closes: false,
        },
        Case {
            name: "second HELLO",
            bytes: frame_of(tag::HELLO, &hello_again),
            hangup: false,
            code: err::STATE,
            msg_contains: "HELLO",
            closes: false,
        },
    ];

    let (_svc, server) = start::<i32>(base_config(), loopback());
    for case in cases {
        let mut s = raw_hello(server.local_addr(), "fuzzer");
        s.write_all(&case.bytes).unwrap();
        if case.hangup {
            s.shutdown(std::net::Shutdown::Write).unwrap();
        }
        let (t, payload) = read_reply(&mut s);
        assert_eq!(t, tag::ERR, "{}: expected a typed error frame", case.name);
        assert_eq!(payload[0], case.code, "{}: error code", case.name);
        let msg = String::from_utf8_lossy(&payload[1..]);
        assert!(
            msg.contains(case.msg_contains),
            "{}: message {msg:?} should mention {:?}",
            case.name,
            case.msg_contains
        );
        if case.closes {
            assert!(
                matches!(
                    frame::read_frame(&mut s, 1 << 20, &ReadOpts::default()),
                    Err(FrameError::Closed) | Err(FrameError::Eof)
                ),
                "{}: connection must close after a desync",
                case.name
            );
        } else {
            // Payload-level failure: the stream is still at a frame
            // boundary, so the connection keeps serving.
            frame::write_frame(&mut s, tag::PING, &[]).unwrap();
            let (t, _) = read_reply(&mut s);
            assert_eq!(t, tag::PONG, "{}: connection must keep serving", case.name);
        }
    }

    // A well-typed client is still served after the whole corpus.
    let mut ok = Client::<i32>::connect(server.local_addr(), "after").unwrap();
    ok.ping().unwrap();
    server.shutdown();
}

#[test]
fn an_unsorted_chunk_is_rejected_and_the_session_stays_usable() {
    let (_svc, server) = start::<i32>(base_config(), loopback());
    let mut client = Client::<i32>::connect(server.local_addr(), "bumpy").unwrap();
    let sid = client.open(1).unwrap();
    let verdict = client.feed(sid, 0, &[5, 3, 4]).unwrap_err();
    assert!(
        matches!(verdict, mergeflow::Error::InvalidInput(_)),
        "typed invalid-input, got: {verdict}"
    );
    // The rejection admitted nothing; the same run continues cleanly.
    client.feed(sid, 0, &[1, 2, 3]).unwrap();
    client.feed(sid, 0, &[4, 5]).unwrap();
    client.seal_run(sid, 0).unwrap();
    let (_, out) = client.seal(sid).unwrap();
    assert_eq!(out, vec![1, 2, 3, 4, 5]);
    server.shutdown();
}

// ---------------------------------------------------------------------
// Multi-tenant admission under concurrency (the acceptance scenario).
// ---------------------------------------------------------------------

#[test]
fn multi_tenant_quotas_busy_verdicts_and_a_mid_stream_kill() {
    let scfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        tenant_quota_bytes: 64 << 10, // 64 KiB in flight per tenant
        lease_ms: 0,
        ..Default::default()
    };
    let (svc, server) = start::<i32>(base_config(), scfg);
    let addr = server.local_addr().to_string();

    // Four well-behaved tenants stream concurrent sessions, each well
    // under its own quota (3 × 2000 × 4 B = 24 KiB in flight).
    let workers: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c =
                    Client::<i32>::connect(&addr, &format!("tenant-{i}")).unwrap();
                let runs =
                    gen_sorted_runs(WorkloadKind::Uniform, 3, 2_000, 0xBEEF + i as u64);
                let sid = c.open(runs.len()).unwrap();
                for (r, run) in runs.iter().enumerate() {
                    for chunk in run.chunks(500) {
                        c.feed(sid, r, chunk).unwrap();
                    }
                    c.seal_run(sid, r).unwrap();
                }
                let (_, out) = c.seal(sid).unwrap();
                let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
                expected.sort_unstable();
                assert_eq!(out, expected, "tenant-{i} output under concurrency");
            })
        })
        .collect();

    // A fifth client is killed mid-stream while the others are running.
    {
        let mut casualty = Client::<i32>::connect(&addr, "casualty").unwrap();
        let sid = casualty.open(1).unwrap();
        let (chunk, _) = gen_sorted_pair(WorkloadKind::Uniform, 2_000, 1, 7);
        casualty.feed(sid, 0, &chunk).unwrap();
        // Dropped without sealing.
    }

    // A hog blows straight through its quota with one 160 KiB one-shot:
    // the verdict is a fail-fast BUSY, not a hang — and nothing stays
    // charged, so a quota-sized retry is admitted immediately.
    let mut hog = Client::<i32>::connect(&addr, "hog").unwrap();
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 20_000, 20_000, 8);
    let started = Instant::now();
    let verdict = hog.merge(&a, &b).unwrap_err();
    assert!(is_busy(&verdict), "expected a BUSY verdict, got: {verdict}");
    assert!(started.elapsed() < Duration::from_secs(5), "BUSY must be fail-fast");
    let (sa, sb) = gen_sorted_pair(WorkloadKind::Uniform, 1_000, 1_000, 9);
    let (_, small) = hog.merge(&sa, &sb).unwrap();
    assert_eq!(small.len(), 2_000, "the quota-sized retry is admitted");

    for w in workers {
        w.join().expect("tenant thread");
    }
    wait_for("casualty reaped", || svc.stats().sessions_reaped.get() >= 1);
    wait_for("quiescent resident bytes", || svc.stats().resident_bytes.get() == 0);
    let stats = svc.stats();
    assert!(stats.busy_rejections.get() >= 1, "the hog's verdict is counted");
    assert_eq!(
        stats.submitted.get(),
        stats.completed.get() + stats.rejected.get(),
        "BUSY verdicts and reaped sessions never enter the job ledger"
    );

    let text = hog.stats().unwrap();
    assert!(text.contains("tenant hog:"), "{text}");
    assert!(text.contains("tenant tenant-0:"), "{text}");
    assert!(text.contains("tenant casualty:"), "{text}");
    server.shutdown();
}
