//! Property-based invariants (in-tree mini-proptest; see
//! `mergeflow::testutil`) over the paper's core claims:
//!
//! - every parallel algorithm ≡ the sequential merge, for every p;
//! - SPM ≡ regular for every (L, p);
//! - partition points are exact-equisized and consistent;
//! - sorts ≡ std sort;
//! - merge output is sorted and a permutation of the inputs.

use mergeflow::baselines::{
    akl_santoro_merge, bitonic_merge, bitonic_sort, deo_sarkar_merge, shiloach_vishkin_merge,
};
use mergeflow::mergepath::diagonal::{
    diagonal_intersection, diagonal_intersection_walk, is_valid_split,
};
use mergeflow::mergepath::{
    cache_efficient_sort, loser_tree_merge, merge_into, parallel_kway_merge, parallel_merge,
    parallel_merge_sort, partition_kway_merge_path, partition_merge_path,
    segmented_parallel_merge, CacheSortConfig, SegmentedConfig,
};
use mergeflow::rng::Xoshiro256;
use mergeflow::testutil::{any_vec, sorted_vec, Prop};

fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
    let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
    v.sort();
    v
}

fn gen_pair(rng: &mut Xoshiro256) -> (Vec<i64>, Vec<i64>) {
    // Mix of value densities: heavy duplicates to nearly unique.
    let universe = [4i64, 64, 1 << 20][rng.range(0, 3)];
    (
        sorted_vec(rng, 0..200, -universe..universe),
        sorted_vec(rng, 0..200, -universe..universe),
    )
}

#[test]
fn prop_all_parallel_merges_agree_with_sequential() {
    Prop::new(0x1001).cases(150).run(
        |rng| {
            let (a, b) = gen_pair(rng);
            let p = rng.range(1, 17);
            (a, b, p)
        },
        |(a, b, p)| {
            let expected = oracle(a, b);
            let n = a.len() + b.len();
            let run = |f: &dyn Fn(&[i64], &[i64], &mut [i64], usize)| {
                let mut out = vec![0i64; n];
                f(a, b, &mut out, *p);
                out == expected
            };
            run(&parallel_merge)
                && run(&shiloach_vishkin_merge)
                && run(&akl_santoro_merge)
                && run(&deo_sarkar_merge)
                && run(&bitonic_merge)
        },
    );
}

#[test]
fn prop_segmented_equals_regular_for_all_configs() {
    Prop::new(0x1002).cases(120).run(
        |rng| {
            let (a, b) = gen_pair(rng);
            let l = rng.range(1, 100);
            let p = rng.range(1, 9);
            (a, b, (l, p))
        },
        |(a, b, (l, p))| {
            let expected = oracle(a, b);
            let mut out = vec![0i64; a.len() + b.len()];
            segmented_parallel_merge(
                a,
                b,
                &mut out,
                SegmentedConfig { segment_len: *l, threads: *p },
            );
            out == expected
        },
    );
}

#[test]
fn prop_partition_is_exact_and_consistent() {
    Prop::new(0x1003).cases(200).run(
        |rng| {
            let (a, b) = gen_pair(rng);
            let p = rng.range(1, 33);
            (a, b, p)
        },
        |(a, b, p)| {
            let n = a.len() + b.len();
            let segs = partition_merge_path(a, b, *p);
            // Equisized ±1, contiguous, covering.
            let mut ok = segs.len() == *p;
            let (lo, hi) = (n / *p, n.div_ceil(*p));
            let mut at = 0usize;
            for s in &segs {
                ok &= s.out_range.start == at;
                ok &= (lo..=hi).contains(&s.out_range.len());
                ok &= s.out_range.len() == s.a_range.len() + s.b_range.len();
                at = s.out_range.end;
            }
            ok && at == n
        },
    );
}

#[test]
fn prop_diagonal_search_matches_walk_and_is_valid() {
    Prop::new(0x1004).cases(200).run(
        |rng| {
            let (a, b) = gen_pair(rng);
            let d = rng.range(0, a.len() + b.len() + 2).min(a.len() + b.len());
            (a, b, d)
        },
        |(a, b, d)| {
            let fast = diagonal_intersection(a, b, *d);
            let slow = diagonal_intersection_walk(a, b, *d);
            fast == slow && is_valid_split(a, b, fast) && fast.diagonal() == *d
        },
    );
}

#[test]
fn prop_sorts_agree_with_std() {
    Prop::new(0x1005).cases(60).run(
        |rng| {
            let v = any_vec(rng, 0..800, -1000..1000);
            let p = rng.range(1, 9);
            (v, p)
        },
        |(v, p)| {
            let mut expected = v.clone();
            expected.sort();
            let mut s1 = v.clone();
            parallel_merge_sort(&mut s1, *p);
            let mut s2 = v.clone();
            cache_efficient_sort(
                &mut s2,
                CacheSortConfig { cache_elems: 128, threads: *p },
            );
            let mut s3 = v.clone();
            bitonic_sort(&mut s3, *p);
            s1 == expected && s2 == expected && s3 == expected
        },
    );
}

#[test]
fn prop_merge_output_sorted_permutation() {
    Prop::new(0x1006).cases(150).run(
        |rng| gen_pair(rng),
        |(a, b)| {
            let mut out = vec![0i64; a.len() + b.len()];
            merge_into(a, b, &mut out);
            let sorted = out.windows(2).all(|w| w[0] <= w[1]);
            let mut expected: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
            expected.sort();
            sorted && out == expected
        },
    );
}

fn gen_runs(rng: &mut Xoshiro256) -> Vec<Vec<i64>> {
    let k = rng.range(0, 9);
    let universe = [4i64, 64, 1 << 20][rng.range(0, 3)];
    (0..k)
        .map(|_| sorted_vec(rng, 0..120, -universe..universe))
        .collect()
}

/// K-way analogue of `partition.rs::check_partition`, §5 multiselection
/// generalised: segments tile the output, each run's ranges tile the
/// run, lengths are equisized ±1, and per-segment loser-tree merges
/// concatenate to the sequential k-way oracle.
#[test]
fn prop_kway_partition_invariants() {
    Prop::new(0x1008).cases(120).run(
        |rng| {
            let runs = gen_runs(rng);
            let p = rng.range(1, 17);
            (runs, p)
        },
        |(runs, p)| {
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let n: usize = refs.iter().map(|r| r.len()).sum();
            let segs = partition_kway_merge_path(&refs, *p);
            let mut ok = segs.len() == *p;
            // Output tiling, equisized ±1, per-segment length agreement.
            let (lo, hi) = (n / *p, n.div_ceil(*p));
            let mut at = 0usize;
            for s in &segs {
                ok &= s.out_range.start == at;
                ok &= (lo..=hi).contains(&s.out_range.len());
                ok &= s.out_range.len() == s.run_ranges.iter().map(|r| r.len()).sum::<usize>();
                at = s.out_range.end;
            }
            ok &= at == n;
            // Each run's ranges tile the run.
            for (j, r) in refs.iter().enumerate() {
                if segs.is_empty() {
                    break;
                }
                ok &= segs[0].run_ranges[j].start == 0;
                ok &= segs[segs.len() - 1].run_ranges[j].end == r.len();
                for w in segs.windows(2) {
                    ok &= w[0].run_ranges[j].end == w[1].run_ranges[j].start;
                }
            }
            // Per-segment merges concatenate to the sequential oracle.
            let mut expected = vec![0i64; n];
            loser_tree_merge(&refs, &mut expected);
            let mut got = vec![0i64; n];
            for s in &segs {
                let parts: Vec<&[i64]> = s
                    .run_ranges
                    .iter()
                    .zip(&refs)
                    .map(|(r, run)| &run[r.clone()])
                    .collect();
                loser_tree_merge(&parts, &mut got[s.out_range.clone()]);
            }
            ok && got == expected
        },
    );
}

#[test]
fn prop_flat_kway_merge_equals_loser_tree() {
    Prop::new(0x1009).cases(100).run(
        |rng| {
            let runs = gen_runs(rng);
            let p = rng.range(1, 17);
            (runs, p)
        },
        |(runs, p)| {
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let n: usize = refs.iter().map(|r| r.len()).sum();
            let mut expected = vec![0i64; n];
            loser_tree_merge(&refs, &mut expected);
            let mut got = vec![0i64; n];
            parallel_kway_merge(&refs, &mut got, *p, None);
            got == expected
        },
    );
}

#[test]
fn prop_merge_idempotent_under_split_merge() {
    // Merging the two halves of a sorted array reproduces it — a
    // round-trip invariant connecting partition and merge.
    Prop::new(0x1007).cases(100).run(
        |rng| sorted_vec(rng, 0..400, -500..500),
        |v| {
            let mid = v.len() / 2;
            let mut out = vec![0i64; v.len()];
            parallel_merge(&v[..mid], &v[mid..], &mut out, 4);
            out == *v
        },
    );
}
