//! Streaming compaction ingest, end to end: the `CompactionSession`
//! protocol must produce output bit-identical to the one-shot
//! `Compact` oracle under every workload kind, chunking pattern, and
//! rejection scenario — and must demonstrably overlap ingest with
//! merging (eager shards dispatched before the final seal).

use mergeflow::bench::workload::{gen_sorted_runs, WorkloadKind};
use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use std::time::{Duration, Instant};

fn base_config() -> MergeflowConfig {
    MergeflowConfig {
        workers: 2,
        threads_per_job: 2,
        queue_capacity: 256,
        max_batch: 8,
        batch_timeout_us: 100,
        backend: Backend::Native,
        // Tests opt into the segmented routes explicitly.
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Auto,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    }
}

fn sorted_oracle(runs: &[Vec<i32>]) -> Vec<i32> {
    let mut v: Vec<i32> = runs.iter().flatten().copied().collect();
    v.sort_unstable();
    v
}

/// Property sweep: interleaved chunked feeds across runs — including
/// empty chunks, a mid-stream unsorted-chunk rejection and a boundary
/// violation rejection per session, staggered run seals — must match
/// the one-shot `Compact` submission of the very same runs bit for
/// bit, for every workload kind, with eager dispatch enabled.
#[test]
fn streamed_matches_one_shot_across_workloads() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 300;
    let svc = MergeService::start(cfg).unwrap();
    // Cycle of chunk lengths; 0 exercises the empty-chunk no-op.
    let chunk_lens = [97usize, 0, 256, 33, 511];
    for (w, kind) in WorkloadKind::all().iter().enumerate() {
        for (case, &(k, run_len)) in
            [(1usize, 2000usize), (3, 700), (5, 1500)].iter().enumerate()
        {
            let runs = gen_sorted_runs(*kind, k, run_len, 0x57AE + (w * 10 + case) as u64);
            let expected = sorted_oracle(&runs);

            // One-shot oracle through the service itself.
            let one_shot = svc
                .submit_blocking(JobKind::Compact { runs: runs.clone() })
                .unwrap();
            assert_eq!(one_shot.output, expected, "{kind:?} k={k} one-shot");

            // Streamed: interleave chunks across runs.
            let mut session = svc.open_compaction(k).unwrap();
            let mut offs = vec![0usize; k];
            let mut c = case; // stagger the chunk-length cycle per case
            while offs.iter().zip(&runs).any(|(&o, r)| o < r.len()) {
                for i in 0..k {
                    if offs[i] >= runs[i].len() {
                        continue;
                    }
                    let len = chunk_lens[c % chunk_lens.len()];
                    c += 1;
                    let end = (offs[i] + len).min(runs[i].len());
                    session.feed(i, runs[i][offs[i]..end].to_vec()).unwrap();
                    offs[i] = end;
                    // Stagger seals: even runs seal as soon as they
                    // end, odd runs only at session seal.
                    if offs[i] == runs[i].len() && i % 2 == 0 {
                        session.seal_run(i).unwrap();
                    }
                }
            }
            // Mid-stream rejections must not disturb admitted data:
            // run k-1 is still open iff k-1 is odd; aim at an open run
            // when one exists.
            if k > 1 {
                let open = if (k - 1) % 2 == 1 { k - 1 } else { k - 2 };
                if open % 2 == 1 {
                    assert!(
                        session.feed(open, vec![5, 3]).is_err(),
                        "unsorted chunk must be rejected mid-stream"
                    );
                    if let Some(&last) = runs[open].last() {
                        if last > i32::MIN {
                            assert!(
                                session.feed(open, vec![last - 1]).is_err(),
                                "boundary violation must be rejected mid-stream"
                            );
                        }
                    }
                }
            }
            let res = session.seal().unwrap().wait().unwrap();
            assert_eq!(res.output, expected, "{kind:?} k={k} streamed");
            assert_eq!(
                res.output, one_shot.output,
                "{kind:?} k={k} streamed vs one-shot"
            );
        }
    }
    svc.shutdown();
}

/// Acceptance: a compaction fed in ≥ 4 chunks per run overlaps ingest
/// with merging — the `eager_shards` counter proves at least one shard
/// was dispatched *before* the session's final `seal()` — and still
/// produces bit-identical output, reported as "native-kway-streamed".
#[test]
fn eager_shards_dispatch_before_seal() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 1024;
    let svc = MergeService::start(cfg).unwrap();
    // Four identical ascending runs: the frontier is deterministic
    // (min of last fed keys), so after all 16 chunks are admitted the
    // settled prefix is 4 · 4095 elements — far past the threshold.
    let k = 4usize;
    let run: Vec<i32> = (0..4096).collect();
    let runs: Vec<Vec<i32>> = (0..k).map(|_| run.clone()).collect();
    let expected = sorted_oracle(&runs);

    let mut session = svc.open_compaction(k).unwrap();
    for chunk in 0..4 {
        for (i, r) in runs.iter().enumerate() {
            session.feed(i, r[chunk * 1024..(chunk + 1) * 1024].to_vec()).unwrap();
        }
    }
    // All data is admitted but nothing is sealed: any eager shard the
    // dispatcher launches is provably pre-seal. The chunks are already
    // in the queue, so the dispatcher reaches them without further help
    // from this thread — poll the counter.
    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.stats().eager_shards.get() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let eager_before_seal = svc.stats().eager_shards.get();
    assert!(
        eager_before_seal >= 1,
        "dispatcher must launch eager shards before seal()"
    );

    for i in 0..k {
        session.seal_run(i).unwrap();
    }
    let res = session.seal().unwrap().wait().unwrap();
    assert_eq!(res.backend, "native-kway-streamed");
    assert_eq!(res.output, expected, "streamed output must be bit-identical");
    let stats = svc.stats();
    assert_eq!(stats.streamed_jobs.get(), 1);
    assert!(stats.eager_shards.get() >= eager_before_seal);
    assert!(
        stats.stream_shards_completed.get() >= stats.eager_shards.get(),
        "eager and remainder shards all complete"
    );
    assert_eq!(stats.streamed_chunks.get(), 16);
    assert_eq!(stats.streamed_bytes.get(), (4 * 4096 * 4) as u64);
    assert_eq!(stats.completed.get(), 1, "client sees one job");
    svc.shutdown();
}

/// Heavy-duplicate ingest must still overlap: with every key equal
/// across all runs nothing is ever *strictly below* the frontier, so
/// the old bare-key frontier pinned at 0 and such sessions never
/// streamed. The tie-aware frontier (per-run tie settling — see
/// coordinator/session.rs) settles the owner run's duplicates, so
/// eager shards launch before seal even here.
#[test]
fn duplicate_heavy_session_still_streams() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 512;
    let svc = MergeService::start(cfg).unwrap();
    let k = 3usize;
    let runs: Vec<Vec<i32>> = (0..k).map(|_| vec![7; 4096]).collect();
    let mut session = svc.open_compaction(k).unwrap();
    for chunk in 0..4 {
        for (i, r) in runs.iter().enumerate() {
            session.feed(i, r[chunk * 1024..(chunk + 1) * 1024].to_vec()).unwrap();
        }
    }
    // All chunks admitted, nothing sealed: any eager shard is pre-seal.
    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.stats().eager_shards.get() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        svc.stats().eager_shards.get() >= 1,
        "tie-aware frontier must settle duplicates and dispatch eagerly"
    );
    for i in 0..k {
        session.seal_run(i).unwrap();
    }
    let res = session.seal().unwrap().wait().unwrap();
    assert_eq!(res.backend, "native-kway-streamed");
    assert_eq!(res.output, vec![7; k * 4096]);
    svc.shutdown();
}

/// Sessions with no eager overlap fall back to the classic routing —
/// same backends as a by-value submission, streaming purely additive.
#[test]
fn no_overlap_session_degrades_to_classic_routing() {
    let svc = MergeService::start(base_config()).unwrap(); // eager off
    let runs = gen_sorted_runs(WorkloadKind::Uniform, 6, 3000, 9);
    let expected = sorted_oracle(&runs);
    let mut session = svc.open_compaction(6).unwrap();
    for (i, r) in runs.iter().enumerate() {
        session.feed(i, r.clone()).unwrap();
        session.seal_run(i).unwrap();
    }
    let res = session.seal().unwrap().wait().unwrap();
    assert_eq!(res.backend, "native-kway", "no overlap → flat engine tag");
    assert_eq!(res.output, expected);
    assert_eq!(svc.stats().streamed_jobs.get(), 0);
    assert_eq!(svc.stats().kway_jobs.get(), 1);
    svc.shutdown();
}

#[test]
fn seal_with_zero_runs_yields_empty_output() {
    // No data ever flows, so nothing pins the (defaulted) record type
    // for inference — spell it.
    let svc = MergeService::<i32>::start(base_config()).unwrap();
    let session = svc.open_compaction(0).unwrap();
    let res = session.seal().unwrap().wait().unwrap();
    assert!(res.output.is_empty());
    svc.shutdown();
}

#[test]
fn single_chunk_degenerate_session() {
    let svc = MergeService::start(base_config()).unwrap();
    let mut session = svc.open_compaction(1).unwrap();
    session.feed(0, vec![1, 2, 2, 7]).unwrap();
    let res = session.seal().unwrap().wait().unwrap();
    assert_eq!(res.output, vec![1, 2, 2, 7]);
    assert_eq!(res.backend, "native", "single run returns by move");
    svc.shutdown();
}

/// Frontier-driven reclamation: once eager shards are planned, the
/// settled run prefixes are dropped from the session buffers, so a
/// long-lived streamed session holds O(unsettled) bytes — the
/// `resident_bytes` gauge shrinks as the frontier advances even while
/// the session keeps every run open.
#[test]
fn streamed_session_holds_o_unsettled_bytes() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 1024;
    let svc = MergeService::start(cfg).unwrap();
    let k = 4usize;
    let run: Vec<i32> = (0..4096).collect();
    let total_bytes = (k * run.len() * 4) as u64;

    let mut session = svc.open_compaction(k).unwrap();
    for chunk in 0..4 {
        for i in 0..k {
            session.feed(i, run[chunk * 1024..(chunk + 1) * 1024].to_vec()).unwrap();
        }
    }
    // Identical ascending runs: after all 16 chunks the settled prefix
    // is k·4095 elements, so nearly everything is plannable. Poll until
    // the dispatcher has planned, reclaimed, and the eager shards have
    // retired their estimates — the live figure must fall to a small
    // fraction of what was fed, while `reclaimed_bytes` records the
    // dropped prefixes.
    let deadline = Instant::now() + Duration::from_secs(10);
    while (svc.stats().resident_bytes.get() * 4 >= total_bytes
        || svc.stats().reclaimed_bytes.get() == 0)
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = svc.stats();
    assert!(
        stats.reclaimed_bytes.get() >= total_bytes / 2,
        "settled prefixes must be reclaimed (reclaimed={} of {total_bytes} fed)",
        stats.reclaimed_bytes.get()
    );
    assert!(
        stats.resident_bytes.get() * 4 < total_bytes,
        "live bytes must be O(unsettled), got {} of {total_bytes} fed",
        stats.resident_bytes.get()
    );

    for i in 0..k {
        session.seal_run(i).unwrap();
    }
    let res = session.seal().unwrap().wait().unwrap();
    let mut expected: Vec<i32> = (0..k).flat_map(|_| run.clone()).collect();
    expected.sort_unstable();
    assert_eq!(res.output, expected, "reclamation must not disturb the output");
    // Quiescence: the session's ingest and every shard estimate are
    // released once the job completes.
    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.stats().resident_bytes.get() != 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(svc.stats().resident_bytes.get(), 0, "gauge drains at quiescence");
    assert!(svc.stats().peak_resident_bytes() > 0);
    svc.shutdown();
}

/// Duplicate-heavy reclamation: with every key equal the tie-aware
/// frontier settles only the owner run's duplicates, so reclamation
/// drains the owner while the other runs stay live — still strictly
/// less than everything fed, and bit-identical at seal.
#[test]
fn duplicate_heavy_session_reclaims_owner_prefix() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 512;
    let svc = MergeService::start(cfg).unwrap();
    let k = 3usize;
    let total_bytes = (k * 4096 * 4) as u64;
    let mut session = svc.open_compaction(k).unwrap();
    for _ in 0..4 {
        for i in 0..k {
            session.feed(i, vec![7; 1024]).unwrap();
        }
    }
    // Wait for both reclamation *and* the dispatched shard estimates
    // to retire — in-flight estimates transiently inflate the gauge.
    let deadline = Instant::now() + Duration::from_secs(10);
    while (svc.stats().reclaimed_bytes.get() == 0
        || svc.stats().resident_bytes.get() >= total_bytes)
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = svc.stats();
    assert!(stats.reclaimed_bytes.get() > 0, "owner-run ties must reclaim");
    assert!(
        stats.resident_bytes.get() < total_bytes,
        "live bytes must shrink below the fed total even under ties"
    );
    for i in 0..k {
        session.seal_run(i).unwrap();
    }
    let res = session.seal().unwrap().wait().unwrap();
    assert_eq!(res.output, vec![7; k * 4096]);
    svc.shutdown();
}

/// Aborting a session mid-reclamation (drop without seal, eager shards
/// already dispatched and prefixes already dropped) must release every
/// live ingest byte via the dispatcher's reaper and leave the service
/// fully operational.
#[test]
fn abort_mid_reclaim_releases_ingest_and_keeps_serving() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 1024;
    let svc = MergeService::start(cfg).unwrap();
    let k = 4usize;
    let run: Vec<i32> = (0..4096).collect();
    {
        let mut session = svc.open_compaction(k).unwrap();
        for chunk in 0..4 {
            for i in 0..k {
                session
                    .feed(i, run[chunk * 1024..(chunk + 1) * 1024].to_vec())
                    .unwrap();
            }
        }
        // Wait for eager planning (and therefore reclamation) to have
        // happened, then drop the session unsealed.
        let deadline = Instant::now() + Duration::from_secs(10);
        while svc.stats().reclaimed_bytes.get() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(svc.stats().reclaimed_bytes.get() > 0, "reclamation ran pre-abort");
    } // <- abort

    // The service still serves — and pumping a job through also drives
    // the dispatcher loop that reaps the aborted session.
    let res = svc
        .submit_blocking(JobKind::Merge { a: vec![1, 3], b: vec![2, 4] })
        .unwrap();
    assert_eq!(res.output, vec![1, 2, 3, 4]);

    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.stats().resident_bytes.get() != 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        svc.stats().resident_bytes.get(),
        0,
        "aborted ingest and in-flight estimates must all be released"
    );
    svc.shutdown();
}

/// A seal racing reclamation: runs are sealed and the session sealed
/// immediately behind a burst of feeds, so the dispatcher's remainder
/// planning races the eager planner's prefix drops. The output must
/// stay bit-identical and the admission ledger balanced.
#[test]
fn seal_racing_reclaim_stays_bit_identical_and_balanced() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 256;
    let svc = MergeService::start(cfg).unwrap();
    for round in 0..6u64 {
        let k = 3usize;
        let runs = gen_sorted_runs(WorkloadKind::Uniform, k, 4000, 0xACE0 + round);
        let expected = sorted_oracle(&runs);
        let mut session = svc.open_compaction(k).unwrap();
        // Burst-feed in small chunks and seal with no pause: the seal
        // message lands while eager planning/reclamation is mid-flight.
        for (i, r) in runs.iter().enumerate() {
            for c in r.chunks(500) {
                session.feed(i, c.to_vec()).unwrap();
            }
            session.seal_run(i).unwrap();
        }
        let res = session.seal().unwrap().wait().unwrap();
        assert_eq!(res.output, expected, "round {round} output must match oracle");
    }
    let stats = svc.stats();
    assert_eq!(
        stats.submitted.get(),
        stats.completed.get() + stats.rejected.get(),
        "ledger must balance at quiescence (no in-flight jobs remain)"
    );
    assert_eq!(stats.completed.get(), 6);
    svc.shutdown();
}

/// The one-shot path *is* the session path: a chunked `compact_chunk_len`
/// configuration must yield bit-identical output to an unchunked one,
/// and large one-shot submissions gain eager overlap for free.
#[test]
fn one_shot_chunked_submission_overlaps_and_matches() {
    let mut cfg = base_config();
    cfg.compact_chunk_len = 512; // split one-shot runs into 8 chunks
    cfg.compact_eager_min_len = 512;
    let svc = MergeService::start(cfg).unwrap();
    let run: Vec<i32> = (0..4096).collect();
    let runs: Vec<Vec<i32>> = (0..4).map(|_| run.clone()).collect();
    let expected = sorted_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.output, expected);
    // Round-robin chunked feeds advance the frontier during ingest, so
    // the dispatcher overlapped — backend tag records it.
    assert_eq!(res.backend, "native-kway-streamed");
    assert!(svc.stats().eager_shards.get() >= 1);
    assert_eq!(svc.stats().streamed_chunks.get(), 32);
    svc.shutdown();
}
