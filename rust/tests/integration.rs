//! Cross-module integration: coordinator + runtime + algorithms
//! working together, including the XLA route when artifacts exist.

use mergeflow::bench::workload::{gen_sorted_pair, gen_sorted_runs, gen_unsorted, WorkloadKind};
use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig, RawConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::mergepath::{loser_tree_merge, parallel_kway_merge};
use mergeflow::runtime::{ArtifactManifest, XlaExecutor};
use std::path::Path;

fn artifacts_present() -> bool {
    Path::new("artifacts/manifest.txt").exists()
}

fn base_config() -> MergeflowConfig {
    MergeflowConfig {
        workers: 2,
        threads_per_job: 2,
        queue_capacity: 128,
        max_batch: 8,
        batch_timeout_us: 100,
        backend: Backend::Native,
        // Tests opt into the segmented routes explicitly.
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        // Tests opt into sharding / eager streaming explicitly.
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Auto,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn service_xla_route_used_for_artifact_shapes() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = base_config();
    cfg.backend = Backend::Auto;
    let svc = MergeService::start(cfg).unwrap();
    if !svc.xla_available() {
        // Auto degrades to native when the runtime cannot start — true
        // whenever the offline PJRT stub (runtime/xla.rs) is in the
        // build, even with artifacts present.
        eprintln!("skipping: XLA runtime unavailable (offline stub build)");
        return;
    }
    // Runtime started: a warmup hang here is a real regression.
    assert!(
        svc.wait_xla_warm(std::time::Duration::from_secs(120)),
        "XLA warmup did not complete"
    );

    // Exact artifact shape → must route to XLA.
    let manifest = ArtifactManifest::load(Path::new("artifacts/manifest.txt")).unwrap();
    let meta = manifest
        .entries()
        .iter()
        .find(|m| m.op == "merge")
        .expect("at least one merge artifact")
        .clone();
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, meta.n_a, meta.n_b, 7);
    let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
    expected.sort_unstable();
    let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
    assert_eq!(res.backend, "xla", "artifact-shaped job should go to XLA");
    assert_eq!(res.output, expected);

    // Off-shape job → native, still correct.
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, meta.n_a + 1, meta.n_b, 8);
    let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
    expected.sort_unstable();
    let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
    assert_eq!(res.backend, "native");
    assert_eq!(res.output, expected);
    assert_eq!(svc.stats().xla_jobs.get(), 1);
    svc.shutdown();
}

#[test]
fn xla_and_native_agree_over_many_seeds() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let Ok(ex) = XlaExecutor::start(Path::new("artifacts")) else {
        eprintln!("skipping: XLA runtime unavailable (offline stub build)");
        return;
    };
    let meta = ex
        .manifest()
        .entries()
        .iter()
        .find(|m| m.op == "merge")
        .unwrap()
        .clone();
    for seed in 0..6u64 {
        for kind in [WorkloadKind::Uniform, WorkloadKind::OneSided, WorkloadKind::Skewed] {
            let (a, b) = gen_sorted_pair(kind, meta.n_a, meta.n_b, seed);
            let got = ex.merge(&meta.name, &a, &b).unwrap();
            let mut expected = vec![0i32; a.len() + b.len()];
            mergeflow::mergepath::merge_into(&a, &b, &mut expected);
            assert_eq!(got, expected, "{:?} seed {seed}", kind);
        }
    }
    ex.shutdown();
}

#[test]
fn service_under_sustained_load_with_mixed_jobs() {
    let svc = MergeService::start(base_config()).unwrap();
    let mut handles = Vec::new();
    for i in 0..30u64 {
        let h = match i % 3 {
            0 => {
                let (a, b) =
                    gen_sorted_pair(WorkloadKind::Uniform, 500 + i as usize, 300, i);
                svc.submit(JobKind::Merge { a, b })
            }
            1 => svc.submit(JobKind::Sort { data: gen_unsorted(700, i) }),
            _ => {
                let runs = (0..4)
                    .map(|j| {
                        let (r, _) =
                            gen_sorted_pair(WorkloadKind::Uniform, 200, 1, i * 10 + j);
                        r
                    })
                    .collect();
                svc.submit(JobKind::Compact { runs })
            }
        }
        .unwrap();
        handles.push(h);
    }
    for h in handles {
        let r = h.wait().unwrap();
        assert!(r.output.windows(2).all(|w| w[0] <= w[1]));
    }
    assert_eq!(svc.stats().completed.get(), 30);
    svc.shutdown();
}

#[test]
fn flat_kway_compaction_end_to_end() {
    // Large multi-run compaction must route to the flat single-pass
    // engine and agree with the sorted oracle.
    let svc = MergeService::start(base_config()).unwrap();
    let runs: Vec<Vec<i32>> = (0..12u64)
        .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 4000, 1, 500 + i).0)
        .collect();
    let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
    expected.sort_unstable();
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, "native-kway");
    assert_eq!(res.output, expected);
    assert_eq!(svc.stats().kway_jobs.get(), 1);
    svc.shutdown();
}

#[test]
fn sharded_compaction_end_to_end() {
    // Acceptance path for rank-sharded compaction: a job whose output
    // exceeds compact_shard_min_len · 2 must execute as ≥ 2
    // CompactShard sub-jobs on the persistent pool, produce output
    // bit-identical to the unsharded flat engine, and be reported as
    // "native-kway-sharded".
    let mut cfg = base_config();
    cfg.compact_sharding = true;
    cfg.compact_shard_min_len = 8192;
    let svc = MergeService::start(cfg).unwrap();
    let runs = gen_sorted_runs(WorkloadKind::Skewed, 10, 6000, 77);
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert!(total > 2 * 8192);
    // Oracle 1: the unsharded flat single-pass engine.
    let mut flat = vec![0i32; total];
    {
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        parallel_kway_merge(&refs, &mut flat, 4, None);
    }
    // Oracle 2: the sequential loser tree (stability baseline).
    let mut seq = vec![0i32; total];
    {
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        loser_tree_merge(&refs, &mut seq);
    }
    assert_eq!(flat, seq);

    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, "native-kway-sharded");
    assert_eq!(res.output, flat, "sharded output must match the flat engine bit for bit");
    let stats = svc.stats();
    assert!(stats.compact_shards.get() >= 2, "expected at least two shards");
    assert_eq!(stats.compact_shards_completed.get(), stats.compact_shards.get());
    assert_eq!(stats.sharded_jobs.get(), 1);
    assert_eq!(stats.completed.get(), 1);
    svc.shutdown();
}

#[test]
fn sharded_compaction_bit_identical_property() {
    // Property sweep: for every workload kind and a spread of shapes —
    // including injected empty runs and the k = 1 edge — the service
    // output equals both parallel_kway_merge and the sequential loser
    // tree, whatever route (sharded / flat / tree / sequential) the
    // job takes.
    let mut cfg = base_config();
    cfg.compact_sharding = true;
    cfg.compact_shard_min_len = 2048;
    let svc = MergeService::start(cfg).unwrap();
    for kind in WorkloadKind::all() {
        for (case, &(k, run_len)) in
            [(1usize, 3000usize), (3, 900), (5, 2000), (9, 1500)].iter().enumerate()
        {
            let mut runs = gen_sorted_runs(kind, k, run_len, 0xA11 + case as u64);
            // Inject empty runs at both ends — they must be invisible.
            runs.insert(0, vec![]);
            runs.push(vec![]);
            let total: usize = runs.iter().map(|r| r.len()).sum();
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut seq = vec![0i32; total];
            loser_tree_merge(&refs, &mut seq);
            let mut flat = vec![0i32; total];
            parallel_kway_merge(&refs, &mut flat, 3, None);
            assert_eq!(seq, flat, "{kind:?} k={k}");
            drop(refs);
            let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
            assert_eq!(res.output, seq, "{kind:?} k={k} route={}", res.backend);
            if k >= 2 && total >= 2 * 2048 {
                assert_eq!(res.backend, "native-kway-sharded", "{kind:?} k={k}");
            }
        }
    }
    // All-empty and k = 0 edges.
    for runs in [vec![], vec![vec![], vec![]]] {
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert!(res.output.is_empty());
    }
    svc.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let mut cfg = base_config();
    cfg.queue_capacity = 1;
    cfg.workers = 1;
    cfg.max_batch = 1;
    let svc = MergeService::start(cfg).unwrap();
    // Pre-generate the jobs, then slam the queue in a tight loop: with
    // capacity 1 and a single slow worker, admission must reject some.
    // Sort jobs are used because their submit-time validation is O(1)
    // (no sortedness scan), so the producer is strictly faster than
    // the consumer in both debug and release builds.
    let jobs: Vec<JobKind> = (0..50u64)
        .map(|i| JobKind::Sort { data: gen_unsorted(512 << 10, i) })
        .collect();
    let mut rejected = 0;
    let mut handles = Vec::new();
    for job in jobs {
        match svc.submit(job) {
            Ok(h) => handles.push(h),
            Err(_) => rejected += 1,
        }
    }
    for h in handles {
        h.wait().unwrap();
    }
    assert!(rejected > 0, "expected back-pressure rejections");
    assert_eq!(svc.stats().rejected.get(), rejected);
    svc.shutdown();
}

#[test]
fn config_file_round_trip_drives_service() {
    let toml = r#"
[service]
workers = 3
threads_per_job = 2
backend = "native"

[merge]
segment_len = 512
"#;
    let cfg = MergeflowConfig::from_raw(&RawConfig::parse(toml).unwrap()).unwrap();
    assert_eq!(cfg.workers, 3);
    let svc = MergeService::start(cfg).unwrap();
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 2000, 2000, 1);
    let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
    assert_eq!(res.backend, "native-segmented"); // 4000 >= 2*512
    svc.shutdown();
}

#[test]
fn figures_pipeline_smoke() {
    // The full figure pipeline at a tiny scale — everything composes.
    let t = mergeflow::bench::figures::fig4(4096);
    assert!(t.render().contains("Fig 4"));
    let t = mergeflow::bench::figures::table2();
    assert!(t.render().contains("HyperCore"));
}
