//! The typed-record stability contract, end to end: `(key, payload)`
//! records with dense duplicate keys must come out of **every**
//! compaction backend — sequential loser tree, flat single-pass k-way,
//! rank-sharded, streamed session, pairwise-tree fallback — with
//! payloads bit-identical to the stable sequential oracle (equal keys
//! in run-index-then-offset order). Payloads encode provenance
//! (`run << 32 | offset`), so any instability is visible in the output
//! itself.

use mergeflow::bench::workload::{gen_record_runs, WorkloadKind};
use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use std::time::{Duration, Instant};

type Rec = (u64, u64);

fn base_config() -> MergeflowConfig {
    MergeflowConfig {
        workers: 2,
        threads_per_job: 2,
        queue_capacity: 256,
        max_batch: 8,
        batch_timeout_us: 100,
        backend: Backend::Native,
        // The segmented-route sweeps below opt in explicitly.
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Auto,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    }
}

/// The stable oracle: flatten in run order (offsets already ascending
/// within a run), then stable-sort by key — ties end up in exactly
/// (run index, offset) order.
fn stable_oracle(runs: &[Vec<Rec>]) -> Vec<Rec> {
    let mut v: Vec<Rec> = runs.iter().flatten().copied().collect();
    v.sort_by_key(|r| r.0);
    v
}

/// Dense-duplicate record runs: every key repeats `dup` times within a
/// run and collides across all `k` runs.
fn dup_runs(k: usize, run_len: usize, dup: usize) -> Vec<Vec<Rec>> {
    (0..k)
        .map(|run| {
            (0..run_len)
                .map(|off| ((off / dup) as u64, ((run as u64) << 32) | off as u64))
                .collect()
        })
        .collect()
}

/// Property sweep over every workload kind and shape spread: the
/// one-shot service output must equal the stable oracle bit for bit on
/// the sequential ("native", small totals), flat k-way
/// ("native-kway-typed"), and tree-fallback routes.
#[test]
fn one_shot_stability_across_workloads_and_routes() {
    let svc = MergeService::<Rec>::start(base_config()).unwrap();
    let mut tree_cfg = base_config();
    tree_cfg.kway_flat_max_k = 4; // force the pairwise-tree fallback for k > 4
    let tree_svc = MergeService::<Rec>::start(tree_cfg).unwrap();
    for (w, kind) in WorkloadKind::all().iter().enumerate() {
        for (case, &(k, run_len)) in
            [(2usize, 600usize), (5, 1500), (8, 2000)].iter().enumerate()
        {
            let runs = gen_record_runs(*kind, k, run_len, 0x57AB + (w * 10 + case) as u64);
            let expected = stable_oracle(&runs);
            let res = svc.submit_blocking(JobKind::Compact { runs: runs.clone() }).unwrap();
            assert_eq!(res.output, expected, "{kind:?} k={k} route={}", res.backend);
            let res = tree_svc.submit_blocking(JobKind::Compact { runs }).unwrap();
            assert_eq!(
                res.output, expected,
                "{kind:?} k={k} tree route={}",
                res.backend
            );
            if k > 4 && k * run_len >= 4096 {
                assert_eq!(res.backend, "native", "{kind:?} k={k} must take the tree");
            }
        }
    }
    // Dense duplicates through the flat engine: the hard case.
    let runs = dup_runs(6, 3000, 64);
    let expected = stable_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, "native-kway-typed");
    assert_eq!(res.output, expected, "flat engine must keep tie provenance");
    svc.shutdown();
    tree_svc.shutdown();
}

/// The rank-sharded route must stitch a stable result: shard cuts land
/// *inside* duplicate tie groups, so any run-order mixup at a boundary
/// would reorder payloads.
#[test]
fn sharded_route_is_stable_under_duplicates() {
    let mut cfg = base_config();
    cfg.compact_sharding = true;
    cfg.compact_shard_min_len = 2048;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    let runs = dup_runs(6, 3000, 128);
    let expected = stable_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, "native-kway-sharded");
    assert_eq!(res.output, expected, "shard boundaries must respect tie order");
    assert!(svc.stats().compact_shards.get() >= 2);
    // A duplicate-dense workload kind through the same route.
    let runs = gen_record_runs(WorkloadKind::Skewed, 5, 4000, 77);
    let expected = stable_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.output, expected);
    svc.shutdown();
}

/// The streamed session path: chunked interleaved feeds of
/// duplicate-heavy record runs, with at least one eager shard provably
/// dispatched *before* `seal()` (the tie-aware frontier is what makes
/// that possible — bare-key frontiers pin at 0 on all-duplicate keys),
/// and output still bit-identical to the stable oracle.
#[test]
fn streamed_route_is_stable_and_overlaps_under_duplicates() {
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 512;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    let k = 4usize;
    let run_len = 4096usize;
    // dup == run_len: every key of every run is identical — the
    // worst case for the frontier, the sharpest case for stability.
    let runs = dup_runs(k, run_len, run_len);
    let expected = stable_oracle(&runs);
    let mut session = svc.open_compaction(k).unwrap();
    for chunk in 0..4 {
        for (i, r) in runs.iter().enumerate() {
            session
                .feed(i, r[chunk * 1024..(chunk + 1) * 1024].to_vec())
                .unwrap();
        }
    }
    // All data admitted, nothing sealed: any eager shard is pre-seal.
    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.stats().eager_shards.get() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        svc.stats().eager_shards.get() >= 1,
        "tie-aware frontier must settle the owner run's duplicates pre-seal"
    );
    for i in 0..k {
        session.seal_run(i).unwrap();
    }
    let res = session.seal().unwrap().wait().unwrap();
    assert_eq!(res.backend, "native-kway-streamed");
    assert_eq!(res.output, expected, "streamed ties must keep provenance");
    assert_eq!(svc.stats().completed.get(), 1);
    svc.shutdown();
}

/// The forced in-place route (`"native-inplace"`): the rotation-based
/// symMerge kernel under the Merge Path partition must honour the
/// stable tie contract exactly like the allocating kernels — pairwise
/// merges and 2-run compactions with dense duplicates, bit for bit
/// against the stable oracle.
#[test]
fn inplace_route_is_stable_under_duplicates() {
    let mut cfg = base_config();
    cfg.inplace = InplaceMode::Always;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    // Pairwise: all of A's ties must precede B's. Shapes cover dense
    // duplicates, all-keys-equal, and a degenerate one-record side.
    let gen = |src: u64, n: usize, dup: usize| {
        (0..n)
            .map(|off| ((off / dup) as u64, (src << 32) | off as u64))
            .collect::<Vec<Rec>>()
    };
    for &(na, nb, dup) in &[(3000usize, 3000usize, 64usize), (5000, 700, 5000), (1, 4000, 1)] {
        let (a, b) = (gen(0, na, dup), gen(1, nb, dup));
        let mut expected: Vec<Rec> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_by_key(|r| r.0);
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native-inplace", "na={na} nb={nb} dup={dup}");
        assert_eq!(res.output, expected, "na={na} nb={nb} dup={dup}: A-ties precede B's");
    }
    // A 2-run compaction takes the same kernel through the session
    // machinery (run 0's ties must precede run 1's).
    let runs = dup_runs(2, 3000, 128);
    let expected = stable_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, "native-inplace");
    assert_eq!(res.output, expected, "2-run compact ties must keep run order");
    // Every workload kind through the forced route.
    for (w, kind) in WorkloadKind::all().iter().enumerate() {
        let runs = gen_record_runs(*kind, 2, 2500, 0x1A7E + w as u64);
        let expected = stable_oracle(&runs);
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-inplace", "{kind:?}");
        assert_eq!(res.output, expected, "{kind:?}");
    }
    assert_eq!(
        svc.stats().inplace_jobs.get(),
        (4 + WorkloadKind::all().len()) as u64,
        "every job above must have taken the in-place kernel"
    );
    svc.shutdown();
}

/// The pairwise `"native-segmented"` route (Alg 3), which the backend
/// sweeps above never force: duplicate-heavy keyed records through
/// `Merge` jobs with the segmented route pinned on, across segment
/// lengths including the `L = 1` degenerate and `L` larger than either
/// input run (windows then span whole inputs) — bit-identical to the
/// stable pairwise oracle (all of A's ties precede B's).
#[test]
fn pairwise_segmented_route_is_stable_under_duplicates() {
    // Stable pairwise oracle: concatenate A then B, stable-sort by key.
    let dup_pair = |na: usize, nb: usize, dup: usize| -> (Vec<Rec>, Vec<Rec>) {
        let gen = |src: u64, n: usize| {
            (0..n)
                .map(|off| ((off / dup) as u64, (src << 32) | off as u64))
                .collect::<Vec<Rec>>()
        };
        (gen(0, na), gen(1, nb))
    };
    for &(segment_len, na, nb) in &[
        (1usize, 600usize, 400usize), // L = 1: one output per window
        (64, 3000, 3000),
        (4000, 3000, 5000), // L larger than either input
    ] {
        let mut cfg = base_config();
        cfg.segmented = true;
        cfg.segment_len = segment_len;
        let svc = MergeService::<Rec>::start(cfg).unwrap();
        let (a, b) = dup_pair(na, nb, 50);
        let mut expected: Vec<Rec> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_by_key(|r| r.0);
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native-segmented", "L={segment_len}");
        assert_eq!(res.output, expected, "L={segment_len}: A-ties must precede B's");
        svc.shutdown();
    }
}

/// The `"native-kway-segmented"` route: duplicate-heavy keyed-record
/// compactions through the segmented flat engine, across window
/// lengths including `L = 1` and `L` larger than every run — vs the
/// stable oracle, bit for bit.
#[test]
fn segmented_kway_route_is_stable_under_duplicates() {
    for &(kway_segment_elems, k, run_len) in &[
        (1usize, 4usize, 1200usize), // every output its own window
        (256, 6, 3000),
        (5000, 6, 2000), // window larger than any run (12000 >= 2L)
    ] {
        let mut cfg = base_config();
        cfg.segmented = true;
        cfg.kway_segment_elems = kway_segment_elems;
        let svc = MergeService::<Rec>::start(cfg).unwrap();
        let runs = dup_runs(k, run_len, 64);
        let expected = stable_oracle(&runs);
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway-segmented-typed", "L={kway_segment_elems}");
        assert_eq!(
            res.output, expected,
            "L={kway_segment_elems}: ties must keep run-then-offset order"
        );
        assert_eq!(svc.stats().kway_segmented_jobs.get(), 1);
        svc.shutdown();
    }
    // All five workload kinds through the segmented k-way route (the
    // record generator's keys collide densely for Skewed), vs the
    // stable oracle.
    let mut cfg = base_config();
    cfg.segmented = true;
    cfg.kway_segment_elems = 512;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    for (w, kind) in WorkloadKind::all().iter().enumerate() {
        let runs = gen_record_runs(*kind, 5, 2000, 0x5E60 + w as u64);
        let expected = stable_oracle(&runs);
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway-segmented-typed", "{kind:?}");
        assert_eq!(res.output, expected, "{kind:?}");
    }
    svc.shutdown();
}

/// Sharded and streamed routes with segmented (windowed) sub-merges:
/// the per-shard bounded windows must not disturb the stitched stable
/// order, and the windowed sub-merges must be visible in the stats.
#[test]
fn sharded_and_streamed_routes_stable_with_windowed_submerges() {
    let mut cfg = base_config();
    cfg.segmented = true;
    cfg.kway_segment_elems = 128;
    cfg.compact_sharding = true;
    cfg.compact_shard_min_len = 2048;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    let runs = dup_runs(6, 3000, 128);
    let expected = stable_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, "native-kway-sharded");
    assert_eq!(res.output, expected);
    assert!(svc.stats().segmented_shard_merges.get() >= 2);
    svc.shutdown();

    let mut cfg = base_config();
    cfg.segmented = true;
    cfg.kway_segment_elems = 128;
    cfg.compact_eager_min_len = 512;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    let runs = dup_runs(4, 4096, 4096);
    let expected = stable_oracle(&runs);
    let mut session = svc.open_compaction(4).unwrap();
    for chunk in 0..4 {
        for (i, r) in runs.iter().enumerate() {
            session.feed(i, r[chunk * 1024..(chunk + 1) * 1024].to_vec()).unwrap();
        }
    }
    // Wait for a pre-seal eager shard, so the session provably takes
    // the streamed route (a seal landing in the same batch would fall
    // back to the classic routing).
    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.stats().eager_shards.get() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(svc.stats().eager_shards.get() >= 1, "eager shard must launch pre-seal");
    for i in 0..4 {
        session.seal_run(i).unwrap();
    }
    let res = session.seal().unwrap().wait().unwrap();
    assert_eq!(res.backend, "native-kway-streamed");
    assert_eq!(res.output, expected);
    assert!(svc.stats().segmented_shard_merges.get() >= 1);
    svc.shutdown();
}

/// Acceptance: `MergeService<(u64, u64)>` compacts key-payload runs
/// end-to-end through all three large-job paths — one-shot flat,
/// sharded, and a streamed session — and all three agree with the
/// stable sequential oracle bit for bit.
#[test]
fn typed_service_end_to_end_all_paths_agree() {
    let runs = gen_record_runs(WorkloadKind::Skewed, 6, 5000, 0xACC);
    let expected = stable_oracle(&runs);

    // One-shot flat.
    let flat_svc = MergeService::<Rec>::start(base_config()).unwrap();
    let flat = flat_svc
        .submit_blocking(JobKind::Compact { runs: runs.clone() })
        .unwrap();
    assert_eq!(flat.backend, "native-kway-typed");
    assert_eq!(flat.output, expected);
    flat_svc.shutdown();

    // Sharded.
    let mut cfg = base_config();
    cfg.compact_sharding = true;
    cfg.compact_shard_min_len = 4096;
    let shard_svc = MergeService::<Rec>::start(cfg).unwrap();
    let sharded = shard_svc
        .submit_blocking(JobKind::Compact { runs: runs.clone() })
        .unwrap();
    assert_eq!(sharded.backend, "native-kway-sharded");
    assert_eq!(sharded.output, expected);
    shard_svc.shutdown();

    // Streamed session (interleaved 500-record chunks).
    let mut cfg = base_config();
    cfg.compact_eager_min_len = 1024;
    let stream_svc = MergeService::<Rec>::start(cfg).unwrap();
    let mut session = stream_svc.open_compaction(runs.len()).unwrap();
    for start in (0..5000).step_by(500) {
        for (i, r) in runs.iter().enumerate() {
            session.feed(i, r[start..start + 500].to_vec()).unwrap();
        }
    }
    for i in 0..runs.len() {
        session.seal_run(i).unwrap();
    }
    let streamed = session.seal().unwrap().wait().unwrap();
    assert_eq!(streamed.output, expected, "route={}", streamed.backend);
    stream_svc.shutdown();
}

/// Forced leaf kernels (`merge.kernel = branchless`) through the
/// service: duplicate-heavy record merges must stay bit-identical to
/// the stable oracle, and the backend tag must carry the resolved
/// kernel suffix (which the per-backend counters strip again).
#[test]
fn forced_branchless_kernel_is_stable_and_tagged() {
    let mut cfg = base_config();
    cfg.kernel = MergeKernel::Branchless;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    // Pairwise with dense ties, incl. empty and one-sided inputs.
    let gen = |src: u64, n: usize, dup: usize| -> Vec<Rec> {
        (0..n)
            .map(|off| ((off / dup) as u64, (src << 32) | off as u64))
            .collect()
    };
    for &(na, nb, dup) in
        &[(3000usize, 3000usize, 64usize), (0, 2000, 1), (2500, 0, 50), (1, 4000, 1)]
    {
        let (a, b) = (gen(0, na, dup), gen(1, nb, dup));
        let mut expected: Vec<Rec> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_by_key(|r| r.0);
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native+branchless", "na={na} nb={nb}");
        assert_eq!(res.output, expected, "na={na} nb={nb} dup={dup}");
    }
    // Compactions: the flat typed route keeps its base tag + suffix.
    let runs = dup_runs(6, 3000, 64);
    let expected = stable_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, "native-kway-typed+branchless");
    assert_eq!(res.output, expected);
    // Suffixes are stripped for the per-backend counters; the kernel
    // counter sees every kernel-dispatched job.
    assert_eq!(svc.stats().native_jobs.get(), 4);
    assert_eq!(svc.stats().kway_jobs.get(), 1);
    assert_eq!(svc.stats().kernel_branchless_jobs.get(), 5);
    svc.shutdown();

    // The L = 1 segmented-window degenerate under the forced kernel.
    let mut cfg = base_config();
    cfg.kernel = MergeKernel::Branchless;
    cfg.segmented = true;
    cfg.segment_len = 1;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    let (a, b) = (gen(0, 600, 50), gen(1, 400, 50));
    let mut expected: Vec<Rec> = a.iter().chain(b.iter()).copied().collect();
    expected.sort_by_key(|r| r.0);
    let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
    assert_eq!(res.backend, "native-segmented+branchless");
    assert_eq!(res.output, expected, "L=1 windows under the forced kernel");
    svc.shutdown();
}

/// `merge.kernel = simd` must degrade to branchless for payload
/// records (the suffix shows the kernel that actually ran), and serve
/// scalar keys with the SIMD kernel when the build and CPU support it
/// — bit-identical to the stable oracle either way.
#[test]
fn forced_simd_kernel_degrades_and_serves_scalars() {
    // Payload records can never take the SIMD kernel.
    let mut cfg = base_config();
    cfg.kernel = MergeKernel::Simd;
    let svc = MergeService::<Rec>::start(cfg).unwrap();
    let runs = dup_runs(4, 2000, 64);
    let expected = stable_oracle(&runs);
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(
        res.backend, "native-kway-typed+branchless",
        "payload records degrade to branchless"
    );
    assert_eq!(res.output, expected);
    assert_eq!(svc.stats().kernel_branchless_jobs.get(), 1);
    assert_eq!(svc.stats().kernel_simd_jobs.get(), 0);
    svc.shutdown();

    // Scalar u64 keys: SIMD when compiled in and the CPU has SSE4.2,
    // branchless otherwise — the suffix records which one ran. Equal
    // scalar keys are bit-identical, so the stable contract is
    // trivially preserved even under the in-register networks.
    let mut cfg = base_config();
    cfg.kernel = MergeKernel::Simd;
    let svc = MergeService::<u64>::start(cfg).unwrap();
    let simd_live = cfg!(feature = "simd") && mergeflow::mergepath::cpu_features().sse42;
    let suffix = if simd_live { "+simd" } else { "+branchless" };
    let a: Vec<u64> = (0..4000u64).map(|i| i / 64).collect();
    let b: Vec<u64> = (0..3000u64).map(|i| i / 8).collect();
    let mut expected: Vec<u64> = a.iter().chain(b.iter()).copied().collect();
    expected.sort_unstable();
    let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
    assert_eq!(res.backend, format!("native{suffix}"));
    assert_eq!(res.output, expected);
    // Flat scalar compaction route keeps its base tag + suffix too.
    let runs: Vec<Vec<u64>> = (0..5u64)
        .map(|r| (0..2000u64).map(|i| (i + r) / 16).collect())
        .collect();
    let mut expected: Vec<u64> = runs.iter().flatten().copied().collect();
    expected.sort_unstable();
    let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
    assert_eq!(res.backend, format!("native-kway{suffix}"));
    assert_eq!(res.output, expected);
    if simd_live {
        assert_eq!(svc.stats().kernel_simd_jobs.get(), 2);
    } else {
        assert_eq!(svc.stats().kernel_branchless_jobs.get(), 2);
    }
    svc.shutdown();
}
