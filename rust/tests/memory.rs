//! Assertion-backed peak-memory test for the in-place merge route.
//!
//! This binary registers [`mergeflow::testutil::CountingAlloc`] as its
//! global allocator, so every heap byte the crate touches is counted.
//! The single test (one test per binary keeps the process-global
//! high-water mark clean) proves the ISSUE acceptance criterion
//! directly: the in-place route allocates no full second output
//! buffer, at the kernel level *and* end to end through the service.

#[global_allocator]
static ALLOC: mergeflow::testutil::CountingAlloc = mergeflow::testutil::CountingAlloc;

use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::mergepath::{concat_for_inplace, merge_in_place};
use mergeflow::testutil::CountingAlloc;

const ELEM: usize = std::mem::size_of::<i32>();

/// Peak heap growth while `f` runs, relative to the bytes outstanding
/// when it starts.
fn peak_over_baseline<T>(f: impl FnOnce() -> T) -> (T, usize) {
    CountingAlloc::reset_peak();
    let base = CountingAlloc::current();
    let out = f();
    (out, CountingAlloc::peak().saturating_sub(base))
}

#[test]
fn inplace_route_never_allocates_a_second_output_buffer() {
    // --- Kernel level: concat + symMerge on a 24:1 asymmetric pair.
    // The allocating kernel would need a full `total`-sized output
    // buffer on top of the inputs; the in-place route's only growth is
    // the `reserve_exact(small)` realloc inside `concat_for_inplace`.
    let big_len = 3 << 20; // 12 MiB of i32
    let small_len = 128 << 10; // 512 KiB
    let big: Vec<i32> = (0..big_len as i32).map(|x| x * 2).collect();
    let small: Vec<i32> = (0..small_len as i32).map(|x| x * 2 + 1).collect();
    let mut expected: Vec<i32> = big.iter().chain(small.iter()).copied().collect();
    expected.sort_unstable();

    let total_bytes = (big_len + small_len) * ELEM;
    let small_bytes = small_len * ELEM;
    let (buf, kernel_peak) = peak_over_baseline(|| {
        let (mut buf, mid) = concat_for_inplace(big, small);
        merge_in_place(&mut buf, mid);
        buf
    });
    assert_eq!(buf, expected, "in-place kernel must merge correctly");
    assert!(
        kernel_peak < total_bytes,
        "kernel peak {kernel_peak} B reached the allocating route's \
         output-buffer cost ({total_bytes} B)"
    );
    // The realloc-delta honest bound: growing the big run by the small
    // one, plus slack for recursion bookkeeping.
    assert!(
        kernel_peak <= small_bytes + (256 << 10),
        "kernel peak {kernel_peak} B exceeds min-run growth \
         {small_bytes} B + 256 KiB slack"
    );
    drop(buf);

    // --- Service level: the same pair streamed through a session in
    // bounded chunks (so ingest double-buffering stays ~one chunk) and
    // compacted on the forced in-place route.
    let cfg = MergeflowConfig {
        workers: 1,
        threads_per_job: 2,
        queue_capacity: 256,
        max_batch: 8,
        batch_timeout_us: 100,
        backend: Backend::Native,
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0, // eager off: classic 2-run routing
        memory_budget: 0,
        inplace: InplaceMode::Always,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    };
    let svc = MergeService::start(cfg).unwrap();
    let chunk = 64 << 10; // 256 KiB feeds, generated on the fly

    let (res, svc_peak) = peak_over_baseline(|| {
        let mut session = svc.open_compaction(2).unwrap();
        for (i, (len, f)) in [
            (big_len, (|x| x * 2) as fn(i32) -> i32),
            (small_len, (|x| x * 2 + 1) as fn(i32) -> i32),
        ]
        .into_iter()
        .enumerate()
        {
            for start in (0..len).step_by(chunk) {
                let end = (start + chunk).min(len);
                let data: Vec<i32> = (start as i32..end as i32).map(f).collect();
                session.feed(i, data).unwrap();
            }
            session.seal_run(i).unwrap();
        }
        session.seal().unwrap().wait().unwrap()
    });
    assert_eq!(res.backend, "native-inplace");
    assert_eq!(res.output, expected, "service output must match oracle");
    // The session necessarily holds the runs once (~`total`, plus
    // `Vec`-doubling capacity overshoot); the in-place route then
    // merges *within* those buffers. The allocating route would hold
    // a full `total`-sized output buffer on top — ≥ 2× `total` plus
    // the same overshoot. Asserting strictly under 2× total therefore
    // separates the two routes with a wide margin on both sides.
    assert!(
        svc_peak < 2 * total_bytes,
        "service peak {svc_peak} B reached inputs + a full output \
         buffer (2 × {total_bytes} B): a second output buffer was \
         allocated somewhere on the in-place path"
    );
    let stats = svc.stats();
    assert_eq!(stats.inplace_jobs.get(), 1);
    assert!(stats.peak_resident_bytes() > 0);
    svc.shutdown();
}
