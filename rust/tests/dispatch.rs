//! Sharded control-plane integration: multi-shard routing and work
//! stealing must be *behaviourally invisible* — every job's output and
//! backend tag bit-identical to the single-dispatcher oracle — while
//! session affinity and the shutdown drain ledger hold per shard.
//!
//! The CI stress job re-runs this suite with the shard count pinned via
//! `MERGEFLOW_TEST_DISPATCH_SHARDS` (1, 2, 8); without the variable
//! each test sweeps 1, 2 and 4 shards itself.

use mergeflow::bench::workload::{gen_sorted_pair, gen_sorted_runs, gen_unsorted, WorkloadKind};
use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig};
use mergeflow::coordinator::{JobKind, MergeService};

fn base_config() -> MergeflowConfig {
    MergeflowConfig {
        workers: 2,
        threads_per_job: 2,
        queue_capacity: 256,
        max_batch: 8,
        batch_timeout_us: 100,
        backend: Backend::Native,
        // Deterministic backend routing: segmented / sharded / eager
        // paths stay off so the oracle comparison is about *dispatch*,
        // not planner heuristics.
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Auto,
        kernel: MergeKernel::Auto,
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    }
}

/// Shard counts to exercise: pinned by the CI stress matrix via
/// `MERGEFLOW_TEST_DISPATCH_SHARDS`, otherwise a local 1/2/4 sweep.
fn shard_counts() -> Vec<usize> {
    match std::env::var("MERGEFLOW_TEST_DISPATCH_SHARDS") {
        Ok(v) => {
            let n: usize = v
                .parse()
                .expect("MERGEFLOW_TEST_DISPATCH_SHARDS must be a positive integer");
            assert!(n >= 1, "MERGEFLOW_TEST_DISPATCH_SHARDS must be >= 1");
            vec![n]
        }
        Err(_) => vec![1, 2, 4],
    }
}

/// A deterministic mixed job list for one workload kind: merges, sorts
/// and compactions with varied sizes so jobs spread across shards.
fn job_mix(kind: WorkloadKind) -> Vec<JobKind<i32>> {
    let mut jobs = Vec::new();
    for i in 0..6u64 {
        let (a, b) = gen_sorted_pair(kind, 800 + 37 * i as usize, 600 + 13 * i as usize, i);
        jobs.push(JobKind::Merge { a, b });
        jobs.push(JobKind::Sort { data: gen_unsorted(900 + 11 * i as usize, 100 + i) });
        jobs.push(JobKind::Compact { runs: gen_sorted_runs(kind, 4, 500, 200 + i) });
    }
    jobs
}

/// Run every job through a service with the given shard count and
/// stealing mode; return `(backend, output)` per job in submit order.
fn run_all(
    shards: usize,
    steal: bool,
    jobs: &[JobKind<i32>],
) -> Vec<(String, Vec<i32>)> {
    let mut cfg = base_config();
    cfg.dispatch_shards = shards;
    cfg.dispatch_steal = steal;
    let svc = MergeService::start(cfg).unwrap();
    let handles: Vec<_> = jobs
        .iter()
        .map(|j| svc.submit(j.clone()).unwrap())
        .collect();
    let out = handles
        .into_iter()
        .map(|h| {
            let r = h.wait().unwrap();
            (r.backend.to_string(), r.output)
        })
        .collect();
    svc.shutdown();
    out
}

/// Property: for every workload kind, shard routing (with and without
/// stealing) produces outputs and backend tags bit-identical to the
/// single-dispatcher oracle.
#[test]
fn routing_and_stealing_match_single_dispatcher_oracle() {
    for kind in WorkloadKind::all() {
        let jobs = job_mix(kind);
        let oracle = run_all(1, false, &jobs);
        for shards in shard_counts() {
            for steal in [false, true] {
                let got = run_all(shards, steal, &jobs);
                assert_eq!(got.len(), oracle.len());
                for (i, (g, o)) in got.iter().zip(&oracle).enumerate() {
                    assert_eq!(
                        g.0, o.0,
                        "job {i} backend drifted ({kind:?}, shards={shards}, steal={steal})"
                    );
                    assert_eq!(
                        g.1, o.1,
                        "job {i} output not bit-identical ({kind:?}, shards={shards}, steal={steal})"
                    );
                }
            }
        }
    }
}

/// Session affinity: every message of a streamed compaction session —
/// chunks, run seals and the final seal — is absorbed by exactly one
/// shard (the owner picked by the id hash), never by a stealer.
#[test]
fn streamed_session_messages_land_on_owning_shard() {
    for shards in shard_counts() {
        let mut cfg = base_config();
        cfg.dispatch_shards = shards;
        let svc = MergeService::start(cfg).unwrap();
        let stats = svc.stats_arc();
        let per_shard = || -> Vec<u64> {
            (0..stats.dispatch_shard_count())
                .map(|i| stats.dispatch_shard(i).unwrap().session_msgs.get())
                .collect()
        };
        // Several sessions in sequence: ids differ, so with >1 shard the
        // owners differ, but each session's messages must stay together.
        for s in 0..4u64 {
            let runs = gen_sorted_runs(WorkloadKind::Uniform, 3, 600, 40 + s);
            let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
            expected.sort_unstable();

            let before = per_shard();
            let mut session = svc.open_compaction(runs.len()).unwrap();
            for (i, run) in runs.iter().enumerate() {
                for chunk in run.chunks(150) {
                    session.feed(i, chunk.to_vec()).unwrap();
                }
                session.seal_run(i).unwrap();
            }
            let res = session.seal().unwrap().wait().unwrap();
            assert_eq!(res.output, expected, "session {s} output wrong");
            let after = per_shard();

            // 3 runs × (4 chunks + 1 run seal) + 1 session seal = 16
            // messages, all on one shard.
            let deltas: Vec<u64> =
                after.iter().zip(&before).map(|(a, b)| a - b).collect();
            assert_eq!(
                deltas.iter().sum::<u64>(),
                16,
                "session {s}: message count off (shards={shards}, deltas={deltas:?})"
            );
            assert_eq!(
                deltas.iter().filter(|&&d| d > 0).count(),
                1,
                "session {s}: messages split across shards (shards={shards}, deltas={deltas:?})"
            );
        }
        svc.shutdown();
    }
}

/// Shutdown under load: with every shard's queue holding backlog,
/// `shutdown` must drain all of them — every handle resolved, and the
/// ledger balances (`submitted == completed + rejected`, nothing lost
/// on any shard).
#[test]
fn shutdown_under_load_drains_every_shard() {
    for shards in shard_counts() {
        let mut cfg = base_config();
        cfg.dispatch_shards = shards;
        let svc = MergeService::start(cfg).unwrap();
        let handles: Vec<_> = (0..48u64)
            .map(|i| {
                let (a, b) =
                    gen_sorted_pair(WorkloadKind::Uniform, 20_000, 20_000, i);
                svc.submit(JobKind::Merge { a, b }).unwrap()
            })
            .collect();
        let stats = svc.stats_arc();
        svc.shutdown();
        for (i, h) in handles.iter().enumerate() {
            let res = h.try_wait();
            assert!(
                res.is_some(),
                "job {i} unresolved after shutdown (shards={shards})"
            );
        }
        assert_eq!(stats.submitted.get(), 48);
        assert_eq!(stats.rejected.get(), 0, "no admission pressure expected");
        assert_eq!(
            stats.completed.get(),
            48,
            "drain ledger must balance (shards={shards})"
        );
        // Conservation across the control plane: every job dispatched
        // exactly once, whether by its home shard or a stealer.
        let dispatched: u64 = (0..stats.dispatch_shard_count())
            .map(|i| stats.dispatch_shard(i).unwrap().dispatched.get())
            .sum();
        assert_eq!(dispatched, 48, "dispatch conservation (shards={shards})");
    }
}
