//! HyperCore walkthrough (§6.2): speedups, bank conflicts, and the
//! regular-vs-segmented crossover on the shared-banked-cache many-core.
//!
//! Run: `cargo run --release --example hypercore_sim`

use mergeflow::bench::harness::{fmt_elems, fmt_speedup, Table};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::sim::engine::{MergeAlgo, SimWorkload};
use mergeflow::sim::hypercore::{hypercore_fpga32, simulate_hypercore};
use mergeflow::sim::stream::Stage;

fn main() {
    let spec = hypercore_fpga32();
    println!(
        "HyperCore model: {} cores, {}KB shared {}-way cache, {} banks, hit {}cyc / miss {}cyc",
        spec.cores,
        spec.cache_capacity / 1024,
        spec.cache_ways,
        spec.banks,
        spec.hit_latency,
        spec.miss_latency
    );

    // Cache-resident vs cache-busting sizes (the FPGA cache holds 256K
    // 4-byte keys).
    let cache_elems = spec.cache_capacity / 4;
    let sizes = [cache_elems / 8, cache_elems * 2];
    let cores = [1usize, 4, 16, 32];

    let mut t = Table::new(
        "HyperCore: cycles and bank conflicts",
        &["size/array", "algo", "cores", "cycles", "speedup", "bank conflicts", "cache misses"],
    );
    for &n in &sizes {
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, n, n, 5);
        // Register sink: the paper's FPGA had a write-back latency bug.
        let w = SimWorkload { a: &a, b: &b, writeback: false, stage: Stage::Both };
        for (name, algo) in [
            ("regular", MergeAlgo::MergePath),
            ("segmented", MergeAlgo::Segmented { segment_len: (cache_elems / 3).max(64) }),
        ] {
            let base = simulate_hypercore(&spec, algo, &w, 1).cycles;
            for &p in &cores {
                let r = simulate_hypercore(&spec, algo, &w, p);
                t.row(&[
                    fmt_elems(n),
                    name.into(),
                    p.to_string(),
                    r.cycles.to_string(),
                    fmt_speedup(base as f64 / r.cycles as f64),
                    r.bank_conflicts.to_string(),
                    r.cache.misses().to_string(),
                ]);
            }
        }
    }
    t.print();
    println!("(expected shape: near-linear to 16 cores; for arrays larger than the cache,\n segmented holds its scaling at 32 cores while regular dips — Fig 7/8)");
}
