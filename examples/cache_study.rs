//! Cache study: the §4 claims, demonstrated on the cache simulator.
//!
//! 1. Prop. 15 — with 3-way associativity (and L = C/3 windows) the
//!    three merge streams produce **zero conflict misses**, while a
//!    direct-mapped cache of the same capacity conflicts heavily.
//! 2. LRU vs FIFO on the merge access pattern (§4.2's replacement
//!    discussion).
//! 3. Regular vs Segmented Merge Path total misses as arrays grow past
//!    the cache (the Table 1 effect, per-size).
//!
//! Run: `cargo run --release --example cache_study`

use mergeflow::bench::harness::{fmt_elems, Table};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::sim::cache::{CacheConfig, ReplacementPolicy, SetAssocCache};
use mergeflow::sim::engine::{simulate_merge, MergeAlgo, SimWorkload};
use mergeflow::sim::machine::x5670_12;
use mergeflow::sim::stream::Stage;

/// Replay the SPM window pattern (A, B, S streams of C/3 each) through
/// one cache and report its stats. Bases are chosen adversarially:
/// all three streams map onto the *same* cache sets (worst case —
/// Prop. 15 must hold for any placement).
fn spm_window_pass(cfg: CacheConfig) -> mergeflow::sim::cache::CacheStats {
    let mut c = SetAssocCache::new(cfg);
    let l = cfg.capacity / 3; // bytes per stream window
    let cap = cfg.capacity as u64;
    let (base_a, base_b, base_s) = (0u64, 16 * cap, 32 * cap);
    for i in 0..(l as u64 / 4) {
        c.access(base_a + i * 4, false);
        c.access(base_b + i * 4, false);
        c.access(base_s + i * 4, true);
    }
    c.stats()
}

fn main() {
    // --- 1. Prop. 15: associativity sweep ----------------------------
    let mut t = Table::new(
        "Prop. 15 — SPM window (3 streams x C/3) conflict misses by associativity",
        &["ways", "hits", "compulsory", "conflict", "capacity"],
    );
    for ways in [1usize, 2, 3, 6, 12] {
        let stats = spm_window_pass(CacheConfig {
            capacity: 3 * 4096 * 64,
            line: 64,
            ways,
            policy: ReplacementPolicy::Lru,
        });
        t.row(&[
            ways.to_string(),
            stats.hits.to_string(),
            stats.compulsory.to_string(),
            stats.conflict.to_string(),
            stats.capacity.to_string(),
        ]);
    }
    t.print();
    println!("(>= 3 ways: zero conflicts, exactly as Prop. 15 guarantees)");

    // --- 2. LRU vs FIFO ----------------------------------------------
    let mut t = Table::new(
        "Replacement policy on one SPM window pass",
        &["policy", "misses", "hits"],
    );
    for policy in [ReplacementPolicy::Lru, ReplacementPolicy::Fifo] {
        let stats = spm_window_pass(CacheConfig {
            capacity: 3 * 1024 * 64,
            line: 64,
            ways: 3,
            policy,
        });
        t.row(&[
            format!("{policy:?}"),
            stats.misses().to_string(),
            stats.hits.to_string(),
        ]);
    }
    t.print();

    // --- 3. Regular vs segmented as N grows --------------------------
    let machine = x5670_12().scaled_caches(64);
    let l3_elems = machine.mem.l3.capacity / 4;
    let mut t = Table::new(
        &format!(
            "Regular vs segmented Merge Path, p=8 (scaled L3 = {} elements; odd N keeps the regular\n             algorithm's data-dependent boundaries off line boundaries, while SPM's\n             aligned L/p sub-segments avoid sharing — the Table 1 footnote)",
            l3_elems
        ),
        &[
            "|A|=|B|",
            "reg L3 misses",
            "seg L3 misses",
            "reg invals",
            "seg invals",
            "reg L1 conflicts",
            "seg L1 conflicts",
        ],
    );
    for n in [l3_elems / 4 + 11, l3_elems + 11, 4 * l3_elems + 11, 16 * l3_elems + 11] {
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, n, n, 3);
        let w = SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Both };
        let reg = simulate_merge(&machine, MergeAlgo::MergePath, &w, 8);
        let seg = simulate_merge(
            &machine,
            MergeAlgo::Segmented { segment_len: (l3_elems / 3).max(64) },
            &w,
            8,
        );
        t.row(&[
            fmt_elems(n),
            reg.mem.l3.misses().to_string(),
            seg.mem.l3.misses().to_string(),
            reg.mem.invalidations.to_string(),
            seg.mem.invalidations.to_string(),
            reg.mem.l1.conflict.to_string(),
            seg.mem.l1.conflict.to_string(),
        ]);
    }
    t.print();
    println!("ok");
}
