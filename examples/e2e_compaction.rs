//! End-to-end driver: an LSM-style compaction pipeline served by the
//! mergeflow coordinator — the full system working together on a real
//! small workload (DESIGN.md "E2E" row).
//!
//! Workload: a write-heavy store flushes sorted runs ("SSTables") of
//! ~64K keys; the compactor submits (1) pairwise merge jobs for L0→L1
//! and (2) k-way `Compact` jobs for the lower levels, all through the
//! service's admission queue → batcher → router → worker pool.
//!
//! The run reports throughput, latency quantiles and backend routing,
//! and verifies every output against a numpy-style oracle. Quoted in
//! EXPERIMENTS.md §E2E. The final phase exercises the persistent run
//! store: a memory-budgeted service spills more data than fits in its
//! budget, background compaction folds the levels, and a simulated
//! restart recovers and reassembles everything bit-identically.
//!
//! Run: `cargo run --release --example e2e_compaction`

use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::config::{
    Backend, InplaceMode, MergeKernel, MergeflowConfig, ServerConfig, StoreConfig,
    StorePolicy,
};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::metrics::{fmt_ns, fmt_throughput, Timer};
use mergeflow::rng::Xoshiro256;
use mergeflow::server::{serve, Client};
use mergeflow::store::{LevelScheduler, RunStore, StoreBridge};
use mergeflow::Error;
use std::sync::Arc;

fn sorted_run(seed: u64, len: usize) -> Vec<i32> {
    let (run, _) = gen_sorted_pair(WorkloadKind::Uniform, len, 1, seed);
    run
}

fn main() {
    let runs_l0 = 32usize; // fresh flushes
    let run_len = 64 << 10;
    let levels = 3usize;

    let cfg = MergeflowConfig {
        workers: 4,
        threads_per_job: 2,
        queue_capacity: 256,
        max_batch: 16,
        batch_timeout_us: 100,
        backend: Backend::Auto, // uses XLA artifacts when shapes fit
        segmented: true,        // cache-efficient segmented routes on
        segment_len: 1 << 20,   // cache-efficient path for big merges
        kway_segment_elems: 0,  // auto: C/(k+1) from cache_bytes below
        cache_bytes: 1 << 20,   // pinned so the demo routes identically everywhere
        kway_flat_max_k: 128,   // flat single-pass engine for k-way compactions
        compact_sharding: true,
        compact_shard_min_len: 512 << 10, // rank-shard compactions above 1M keys
        compact_chunk_len: 1 << 20,       // one-shot runs stream in 1M-key chunks
        compact_eager_min_len: 64 << 10,  // eager-merge once 64K ranks settle
        memory_budget: 0,                 // unbudgeted: the demo keeps every route open
        inplace: InplaceMode::Auto,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    };
    println!("config: {cfg:?}");
    let svc = MergeService::start(cfg).expect("service start");

    let mut rng = Xoshiro256::seeded(0xE2E);
    let mut total_elems = 0u64;

    // Phase 1 — L0 flush storm: pairwise merges (some exactly the size
    // of an AOT artifact, exercising the XLA route).
    let mut level: Vec<Vec<i32>> = (0..runs_l0)
        .map(|i| sorted_run(i as u64, run_len))
        .collect();
    // A few artifact-sized jobs (4096 + 4096) mixed into the stream.
    // Wait for background warmup so they demonstrably take the XLA
    // route (the router falls back to native while an artifact is
    // cold, so this only affects which backend serves them).
    if svc.wait_xla_warm(std::time::Duration::from_secs(120)) {
        println!("xla backend warm");
    }
    let wall = Timer::start(); // serving-time clock (excludes warmup)
    let small_jobs: Vec<_> = (0..8)
        .map(|i| {
            let a = sorted_run(1000 + i, 4096);
            let b = sorted_run(2000 + i, 4096);
            svc.submit(JobKind::Merge { a, b }).expect("submit")
        })
        .collect();

    for round in 0..levels {
        let mut handles = Vec::new();
        while level.len() >= 2 {
            let a = level.pop().unwrap();
            let b = level.pop().unwrap();
            total_elems += (a.len() + b.len()) as u64;
            handles.push(svc.submit(JobKind::Merge { a, b }).expect("submit"));
        }
        let leftover = level.pop();
        let mut next: Vec<Vec<i32>> = handles
            .into_iter()
            .map(|h| {
                let r = h.wait().expect("merge job");
                assert!(r.output.windows(2).all(|w| w[0] <= w[1]), "unsorted output!");
                r.output
            })
            .collect();
        next.extend(leftover);
        println!(
            "level {} -> {} runs of ~{} keys",
            round,
            next.len(),
            next.first().map_or(0, |r| r.len())
        );
        level = next;
        if level.len() < 2 {
            break;
        }
    }

    // Phase 2 — k-way compactions of fresh batches through single jobs.
    // Both shapes take the *segmented* flat single-pass engine
    // (k ≤ kway_flat_max_k, and the jobs span at least two auto-sized
    // path windows): every worker thread merges its equisized slice of
    // the output in one pass, walked in (k+1)·L-bounded windows so the
    // live windows stay cache-resident.
    for k in [7usize, 16] {
        let kway: Vec<Vec<i32>> = (0..k)
            .map(|_| sorted_run(rng.next_u64(), 32 << 10))
            .collect();
        let kway_total: usize = kway.iter().map(|r| r.len()).sum();
        total_elems += kway_total as u64;
        let mut expected: Vec<i32> = kway.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc
            .submit_blocking(JobKind::Compact { runs: kway })
            .expect("compact job");
        assert_eq!(res.output, expected, "compaction output mismatch (k={k})");
        assert_eq!(
            res.backend, "native-kway-segmented",
            "expected the segmented flat k-way engine"
        );
        println!(
            "{k}-way compaction: {} keys in {} via {} (single segmented pass)",
            kway_total,
            fmt_ns(res.latency_ns),
            res.backend
        );
    }

    // Phase 3 — one oversized compaction: the dispatcher splits it by
    // output rank into independent CompactShard sub-jobs (output is
    // 1.5M keys ≥ 2 × compact_shard_min_len → 3 shards), which the
    // pool executes like any other jobs; the last shard to finish
    // replies with the stitched result.
    {
        let k = 24usize;
        let giant: Vec<Vec<i32>> = (0..k)
            .map(|_| sorted_run(rng.next_u64(), run_len))
            .collect();
        let giant_total: usize = giant.iter().map(|r| r.len()).sum();
        total_elems += giant_total as u64;
        let mut expected: Vec<i32> = giant.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc
            .submit_blocking(JobKind::Compact { runs: giant })
            .expect("sharded compact job");
        assert_eq!(res.output, expected, "sharded compaction output mismatch");
        assert_eq!(res.backend, "native-kway-sharded", "expected the rank-sharded path");
        println!(
            "{k}-way compaction: {} keys in {} via {} ({} shards)",
            giant_total,
            fmt_ns(res.latency_ns),
            res.backend,
            svc.stats().compact_shards.get(),
        );
    }

    // Phase 4 — streaming ingest: a CompactionSession feeds runs chunk
    // by chunk, round-robin, while the dispatcher eagerly merges every
    // settled rank window — ingest and merge overlap end to end, and
    // at least one eager shard launches before seal() is even called.
    {
        let k = 6usize;
        let chunk_len = 16 << 10;
        let chunks_per_run = 8usize;
        let stream_runs: Vec<Vec<i32>> = (0..k)
            .map(|_| sorted_run(rng.next_u64(), chunk_len * chunks_per_run))
            .collect();
        let stream_total: usize = stream_runs.iter().map(|r| r.len()).sum();
        total_elems += stream_total as u64;
        let mut expected: Vec<i32> = stream_runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let mut session = svc.open_compaction(k).expect("open session");
        for c in 0..chunks_per_run {
            for (i, run) in stream_runs.iter().enumerate() {
                session
                    .feed(i, run[c * chunk_len..(c + 1) * chunk_len].to_vec())
                    .expect("feed chunk");
            }
        }
        let eager_before_seal = svc.stats().eager_shards.get();
        for i in 0..k {
            session.seal_run(i).expect("seal run");
        }
        let res = session
            .seal()
            .expect("seal session")
            .wait()
            .expect("streamed compaction");
        assert_eq!(res.output, expected, "streamed compaction output mismatch");
        assert_eq!(
            res.backend, "native-kway-streamed",
            "expected the streamed route (eager overlap)"
        );
        println!(
            "streamed {k}-way compaction: {} keys in {} via {} \
             ({} eager shards, {} observed before seal)",
            stream_total,
            fmt_ns(res.latency_ns),
            res.backend,
            svc.stats().eager_shards.get(),
            eager_before_seal,
        );
    }

    // Phase 5 — typed records: the same coordinator, generic over
    // keyed records. (key, payload) pairs compact end-to-end with the
    // guaranteed-stable tie order (equal keys keep run-then-offset
    // order), verified against the stable sequential oracle; the
    // non-i32 record type deterministically routes native (XLA
    // artifacts are baked for i32 keys).
    {
        let typed_cfg = MergeflowConfig {
            workers: 4,
            threads_per_job: 2,
            queue_capacity: 64,
            max_batch: 16,
            batch_timeout_us: 100,
            backend: Backend::Native,
            segmented: true,
            segment_len: 0,
            kway_segment_elems: 0,
            cache_bytes: 1 << 20,
            kway_flat_max_k: 64,
            compact_sharding: true,
            compact_shard_min_len: 128 << 10,
            compact_chunk_len: 0,
            compact_eager_min_len: 0,
            memory_budget: 0,
            inplace: InplaceMode::Auto,
            kernel: MergeKernel::Auto,
            // Single dispatcher shard, calibration probes off:
            // deterministic control plane and knob values.
            dispatch_shards: 1,
            dispatch_steal: true,
            calibrate: false,
            shard_floor: 1 << 18,
            artifacts_dir: "artifacts".into(),
        };
        let typed = MergeService::<(u64, u64)>::start(typed_cfg).expect("typed service");
        let k = 8usize;
        let rec_len = 48 << 10;
        let rec_runs: Vec<Vec<(u64, u64)>> = (0..k)
            .map(|run| {
                sorted_run(rng.next_u64(), rec_len)
                    .into_iter()
                    .enumerate()
                    .map(|(off, key)| {
                        let key = (key as i64 - i32::MIN as i64) as u64;
                        (key, ((run as u64) << 32) | off as u64)
                    })
                    .collect()
            })
            .collect();
        total_elems += (k * rec_len) as u64;
        // Stable oracle: flatten in run order, stable-sort by key —
        // ties must come out in run-index-then-offset order.
        let mut expected: Vec<(u64, u64)> = rec_runs.iter().flatten().copied().collect();
        expected.sort_by_key(|r| r.0);
        let res = typed
            .submit_blocking(JobKind::Compact { runs: rec_runs })
            .expect("typed compact job");
        assert_eq!(res.output, expected, "typed compaction must be stable");
        assert_eq!(res.backend, "native-kway-sharded", "384K records → rank shards");
        println!(
            "typed {k}-way compaction: {} (key, payload) records in {} via {} (stable ties)",
            k * rec_len,
            fmt_ns(res.latency_ns),
            res.backend
        );
        typed.shutdown();
    }

    // Phase 6 — the wire layer: the same coordinator surface served
    // over a loopback TCP socket. Two tenants drive it through the
    // typed client — one with a one-shot merge, one streaming a
    // session chunk by chunk — every output oracle-checked, then a
    // clean server shutdown.
    {
        let wire_cfg = MergeflowConfig {
            workers: 2,
            threads_per_job: 2,
            queue_capacity: 64,
            max_batch: 8,
            batch_timeout_us: 100,
            backend: Backend::Native,
            segmented: false,
            segment_len: 0,
            kway_segment_elems: 0,
            cache_bytes: 0,
            kway_flat_max_k: 64,
            compact_sharding: false,
            compact_shard_min_len: 0,
            compact_chunk_len: 0,
            compact_eager_min_len: 16 << 10,
            memory_budget: 0,
            inplace: InplaceMode::Auto,
            kernel: MergeKernel::Auto,
            // Single dispatcher shard, calibration probes off:
            // deterministic control plane and knob values.
            dispatch_shards: 1,
            dispatch_steal: true,
            calibrate: false,
            shard_floor: 1 << 18,
            artifacts_dir: "artifacts".into(),
        };
        let wire_svc = std::sync::Arc::new(
            MergeService::<i32>::start(wire_cfg).expect("wire service"),
        );
        let server = serve(
            std::sync::Arc::clone(&wire_svc),
            ServerConfig { listen: "127.0.0.1:0".into(), ..Default::default() },
        )
        .expect("wire server");
        println!("wire server listening on {}", server.local_addr());

        // Tenant "oneshot": a pairwise merge over the socket.
        let mut one_shot =
            Client::<i32>::connect(server.local_addr(), "oneshot").expect("connect");
        let (wa, wb) = (sorted_run(31, 32 << 10), sorted_run(32, 32 << 10));
        let mut expected: Vec<i32> = wa.iter().chain(&wb).copied().collect();
        expected.sort_unstable();
        let (backend, merged) = one_shot.merge(&wa, &wb).expect("wire merge");
        assert_eq!(merged, expected, "wire merge output mismatch");
        total_elems += merged.len() as u64;
        println!("wire merge: {} keys via {backend}", merged.len());

        // Tenant "streamer": a compaction session fed chunk by chunk.
        let mut streamer =
            Client::<i32>::connect(server.local_addr(), "streamer").expect("connect");
        let k = 4usize;
        let chunk_len = 8 << 10;
        let chunks_per_run = 4usize;
        let stream_runs: Vec<Vec<i32>> = (0..k)
            .map(|i| sorted_run(40 + i as u64, chunk_len * chunks_per_run))
            .collect();
        let mut expected: Vec<i32> = stream_runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let sid = streamer.open(k).expect("wire open");
        for c in 0..chunks_per_run {
            for (r, run) in stream_runs.iter().enumerate() {
                streamer
                    .feed(sid, r, &run[c * chunk_len..(c + 1) * chunk_len])
                    .expect("wire feed");
            }
        }
        for r in 0..k {
            streamer.seal_run(sid, r).expect("wire seal_run");
        }
        let (backend, streamed) = streamer.seal(sid).expect("wire seal");
        assert_eq!(streamed, expected, "wire streamed output mismatch");
        total_elems += streamed.len() as u64;
        println!("wire streamed compaction: {} keys via {backend}", streamed.len());

        // The STATS verb reports both tenants' admission lines.
        let stats = streamer.stats().expect("wire stats");
        assert!(stats.contains("tenant oneshot:"), "missing tenant line:\n{stats}");
        assert!(stats.contains("tenant streamer:"), "missing tenant line:\n{stats}");
        server.shutdown();
        println!("wire server shut down cleanly");
    }

    // Phase 7 — the persistent run store: a memory-budgeted service
    // spills twice its budget's worth of sorted runs to disk while a
    // background LevelScheduler compacts the level-0 backlog, a FLUSH
    // drains the store to policy, and after a simulated restart the
    // surviving runs stream from their run files through a fresh
    // compaction session into one sorted result — oracle-checked bit
    // for bit. The budget is the point: at no moment do 4 MiB of keys
    // sit in memory, yet all of them flow spill → compact → merge.
    {
        let budget = 2 << 20; // 2 MiB resident cap
        let spill_runs = 32usize;
        let spill_len = 32 << 10; // 32 runs × 128 KiB = 4 MiB = 2× budget
        let store_dir = std::env::temp_dir()
            .join(format!("mergeflow-e2e-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&store_dir);
        let spill_cfg = MergeflowConfig {
            workers: 2,
            threads_per_job: 2,
            queue_capacity: 64,
            max_batch: 8,
            batch_timeout_us: 100,
            backend: Backend::Native,
            segmented: false,
            segment_len: 0,
            kway_segment_elems: 0,
            cache_bytes: 0,
            kway_flat_max_k: 64,
            compact_sharding: false,
            compact_shard_min_len: 0,
            compact_chunk_len: 0,
            compact_eager_min_len: 0,
            memory_budget: budget,
            inplace: InplaceMode::Auto,
            kernel: MergeKernel::Auto,
            // Single dispatcher shard, calibration probes off:
            // deterministic control plane and knob values.
            dispatch_shards: 1,
            dispatch_steal: true,
            calibrate: false,
            shard_floor: 1 << 18,
            artifacts_dir: "artifacts".into(),
        };
        // level0_max_runs = 8 keeps every compaction pass (8 × 128 KiB
        // = 1 MiB of ingest) admissible under the 2 MiB budget;
        // level_fanout = 8 keeps L1 within policy for this volume.
        let store_cfg = StoreConfig {
            dir: store_dir.to_string_lossy().into_owned(),
            policy: StorePolicy::Tiered,
            level0_max_runs: 8,
            level_fanout: 8,
            block_bytes: 64 << 10,
            compact_backoff_ms: 5,
        };
        let spill_svc =
            Arc::new(MergeService::<i32>::start(spill_cfg.clone()).expect("spill service"));
        let store = Arc::new(RunStore::<i32>::open(&store_cfg).expect("open store"));
        spill_svc
            .attach_store(Arc::new(StoreBridge::new(
                Arc::clone(&store),
                spill_svc.stats_arc(),
            )))
            .expect("attach store");
        let scheduler = LevelScheduler::start(Arc::clone(&store), Arc::clone(&spill_svc));

        let mut oracle: Vec<i32> = Vec::with_capacity(spill_runs * spill_len);
        for i in 0..spill_runs {
            let run = sorted_run(7_000 + i as u64, spill_len);
            oracle.extend_from_slice(&run);
            // Spills retry on BUSY: while a background compaction holds
            // the budget, admission answers fail-fast Service errors.
            loop {
                match spill_svc.submit(JobKind::Spill { run: run.clone() }) {
                    Ok(h) => {
                        h.wait().expect("spill job");
                        break;
                    }
                    Err(Error::Service(_)) => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(e) => panic!("spill rejected: {e}"),
                }
            }
        }
        oracle.sort_unstable();
        total_elems += (spill_runs * spill_len) as u64;

        // Drain to policy, then stop the scheduler before teardown.
        let flushed = spill_svc.submit_blocking(JobKind::Flush).expect("flush job");
        assert_eq!(flushed.backend, "store-flush");
        scheduler.stop();
        let stats = spill_svc.stats();
        println!(
            "store spill: {} B through a {budget} B budget \
             ({} spills, {} compactions, generation {})",
            stats.store_spilled_bytes.get(),
            stats.store_spills.get(),
            stats.store_compactions.get(),
            store.generation()
        );
        assert!(
            stats.store_spilled_bytes.get() > budget as u64,
            "the phase must push more bytes through the store than the budget"
        );
        print!("{}", store.describe(false));
        spill_svc.shutdown();
        drop(store);

        // Simulated restart: recover the store from disk, then stream
        // every surviving run file block-by-block through a compaction
        // session on a fresh service — the read path never materializes
        // a whole run either.
        let store = RunStore::<i32>::open(&store_cfg).expect("reopen store");
        let (generation, live) = store.snapshot();
        let reader_svc = MergeService::<i32>::start(MergeflowConfig {
            memory_budget: 0,
            ..spill_cfg
        })
        .expect("reader service");
        let mut session =
            reader_svc.open_compaction(live.len()).expect("open final merge");
        for (i, meta) in live.iter().enumerate() {
            let mut reader = store.reader(meta).expect("run reader");
            while let Some(block) = reader.next_block().expect("read block") {
                session.feed(i, block).expect("feed block");
            }
            session.seal_run(i).expect("seal run");
        }
        let merged = session
            .seal()
            .expect("seal final merge")
            .wait()
            .expect("final merge");
        assert_eq!(
            merged.output, oracle,
            "store round-trip (spill → compact → restart → merge) must be bit-identical"
        );
        println!(
            "store round-trip: {} keys from {} surviving runs (generation {}) via {} \
             — oracle-identical",
            merged.output.len(),
            live.len(),
            generation,
            merged.backend
        );
        reader_svc.shutdown();
        let _ = std::fs::remove_dir_all(&store_dir);
    }

    // Collect the artifact-sized jobs (XLA route when artifacts exist).
    for h in small_jobs {
        let r = h.wait().expect("small job");
        total_elems += r.output.len() as u64;
        assert!(r.output.windows(2).all(|w| w[0] <= w[1]));
    }

    let ns = wall.elapsed_ns();
    println!("\n== E2E summary ==");
    println!(
        "processed {} keys end-to-end in {} ({})",
        total_elems,
        fmt_ns(ns),
        fmt_throughput(total_elems, ns)
    );
    println!("{}", svc.stats().snapshot());
    svc.shutdown();
    println!("ok");
}
