//! Quickstart: the three public entry points in ten lines each —
//! parallel merge (Alg 1), segmented cache-efficient merge (Alg 3),
//! and parallel merge sort (§3).
//!
//! Run: `cargo run --release --example quickstart`

use mergeflow::bench::workload::{gen_sorted_pair, gen_unsorted, WorkloadKind};
use mergeflow::mergepath::{
    parallel_merge, parallel_merge_sort, partition_merge_path, segmented_parallel_merge,
    SegmentedConfig,
};
use mergeflow::metrics::{fmt_ns, fmt_throughput, Timer};

fn main() {
    // 1. Parallel merge: two sorted arrays in, one sorted array out.
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 1 << 20, 1 << 20, 1);
    let mut merged = vec![0i32; a.len() + b.len()];
    let t = Timer::start();
    parallel_merge(&a, &b, &mut merged, 4);
    println!(
        "parallel_merge: {} elements in {} ({})",
        merged.len(),
        fmt_ns(t.elapsed_ns()),
        fmt_throughput(merged.len() as u64, t.elapsed_ns())
    );
    assert!(merged.windows(2).all(|w| w[0] <= w[1]));

    // 2. The partition that makes it possible (Thm 14): perfectly
    //    equisized segments, computed without merging anything.
    let segments = partition_merge_path(&a, &b, 8);
    println!(
        "partition into 8: segment lengths = {:?}",
        segments.iter().map(|s| s.len()).collect::<Vec<_>>()
    );

    // 3. Cache-efficient segmented merge (Alg 3): identical output,
    //    cache-sized working set (L = C/3, Prop. 15).
    let mut merged2 = vec![0i32; a.len() + b.len()];
    let t = Timer::start();
    segmented_parallel_merge(
        &a,
        &b,
        &mut merged2,
        SegmentedConfig::for_cache(3 << 20, 4), // 12MB L3 / 4B elements
    );
    println!(
        "segmented_parallel_merge: {} ({})",
        fmt_ns(t.elapsed_ns()),
        fmt_throughput(merged2.len() as u64, t.elapsed_ns())
    );
    assert_eq!(merged, merged2, "both algorithms produce identical output");

    // 4. Parallel merge sort.
    let mut data = gen_unsorted(4 << 20, 2);
    let t = Timer::start();
    parallel_merge_sort(&mut data, 4);
    println!(
        "parallel_merge_sort: {} elements in {} ({})",
        data.len(),
        fmt_ns(t.elapsed_ns()),
        fmt_throughput(data.len() as u64, t.elapsed_ns())
    );
    assert!(data.windows(2).all(|w| w[0] <= w[1]));
    println!("ok");
}
