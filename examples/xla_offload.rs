//! XLA offload: merge through the AOT-compiled JAX/Pallas kernel
//! (L1+L2 of the stack) from rust, and cross-check against the native
//! Merge Path bit-for-bit.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example xla_offload`

use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::mergepath::merge_into;
use mergeflow::runtime::XlaRuntime;

fn main() {
    let rt = match XlaRuntime::open(std::path::Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot open artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts:");
    for m in rt.manifest().entries() {
        println!("  {:<24} op={:<10} |A|={:<7} |B|={:<7}", m.name, m.op, m.n_a, m.n_b);
    }

    let timer = BenchTimer::quick();
    for meta in rt.manifest().entries().to_vec() {
        if meta.op != "merge" {
            continue;
        }
        let exe = rt.merge_executable(&meta.name).expect("compile artifact");
        // Cross-check on several seeds, including adversarial shapes.
        for seed in [1u64, 2, 3] {
            let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, meta.n_a, meta.n_b, seed);
            let got = exe.merge(&a, &b).expect("xla merge");
            let mut expected = vec![0i32; a.len() + b.len()];
            merge_into(&a, &b, &mut expected);
            assert_eq!(got, expected, "{} seed {seed}", meta.name);
        }
        let (a, b) = gen_sorted_pair(WorkloadKind::OneSided, meta.n_a, meta.n_b, 9);
        assert_eq!(
            exe.merge(&a, &b).unwrap(),
            {
                let mut e = vec![0i32; a.len() + b.len()];
                merge_into(&a, &b, &mut e);
                e
            },
            "one-sided"
        );
        println!("  {}: numerics verified (4 cases)", meta.name);
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, meta.n_a, meta.n_b, 11);
        let m = timer.measure(|| {
            std::hint::black_box(exe.merge(&a, &b).unwrap());
        });
        println!(
            "  {}",
            report_line(&meta.name, &m, (meta.n_a + meta.n_b) as u64)
        );
    }
    println!("ok — python never ran: this binary only loaded HLO text via PJRT");
}
