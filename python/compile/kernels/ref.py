"""Pure-jnp / numpy oracles for the Pallas kernels.

These are the correctness ground truth: slow, obvious implementations
mirroring the paper's definitions. The pytest suite asserts the Pallas
kernels and the full L2 model against them (exact equality — integer
keys, no tolerance games).
"""

import numpy as np
import jax.numpy as jnp


def merge_ref_np(a, b):
    """Two-finger stable (A-priority) merge — the paper's Lemma 1 walk."""
    a = np.asarray(a)
    b = np.asarray(b)
    out = np.empty(a.shape[0] + b.shape[0], dtype=a.dtype)
    i = j = k = 0
    while i < len(a) and j < len(b):
        if a[i] <= b[j]:
            out[k] = a[i]
            i += 1
        else:
            out[k] = b[j]
            j += 1
        k += 1
    out[k : k + len(a) - i] = a[i:]
    k += len(a) - i
    out[k:] = b[j:]
    return out


def merge_ref_jnp(a, b):
    """Rank-based merge in pure jnp (the vectorization the kernel uses,
    but without windows/padding — an independent derivation to check
    the kernel's masking logic against)."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    n_a, n_b = a.shape[0], b.shape[0]
    pos_a = jnp.arange(n_a) + jnp.searchsorted(b, a, side="left")
    pos_b = jnp.arange(n_b) + jnp.searchsorted(a, b, side="right")
    out = jnp.zeros(n_a + n_b, dtype=a.dtype)
    out = out.at[pos_a].set(a)
    out = out.at[pos_b].set(b)
    return out


def diagonal_intersection_ref(a, b, diag):
    """O(diag) merge-path walk (mirrors the rust test oracle)."""
    a = np.asarray(a)
    b = np.asarray(b)
    ai = bi = 0
    while ai + bi < diag:
        if ai < len(a) and (bi >= len(b) or a[ai] <= b[bi]):
            ai += 1
        else:
            bi += 1
    return ai, bi


def partition_ref(a, b, segment_len):
    """All segment start points, via the walk oracle: (G + 1, 2)."""
    n = len(a) + len(b)
    num_segments = max(1, -(-n // segment_len)) if n else 1
    points = []
    for g in range(num_segments + 1):
        d = min(g * segment_len, n)
        points.append(diagonal_intersection_ref(a, b, d))
    return np.array(points, dtype=np.int32)
