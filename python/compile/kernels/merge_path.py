"""L1 Pallas kernels: Merge Path on the TPU programming model.

Two kernels implement the paper's two phases (DESIGN.md
§Hardware-Adaptation):

- ``partition_call`` — the cross-diagonal binary search (paper Alg 2),
  one *lane* per partition point, branch-free: ``log2`` iterations of
  compare+select across all diagonals at once. This is the TPU rethink
  of the GPU version's per-SM search (Green et al., ICS'12).

- ``merge_blocks_call`` — the per-segment merge. Instead of the serial
  two-finger walk (hostile to the VPU), each segment's output is
  produced by *rank-based* placement: ``pos(A[i]) = i + |{B < A[i]}|``
  and ``pos(B[j]) = j + |{A <= B[j]}|`` (the ``<=`` keeps the merge
  stable with A-priority, matching the rust implementation bit for
  bit). Ranks come from vectorized ``searchsorted``; the scatter is an
  XLA scatter in interpret mode.

Both kernels run with ``interpret=True``: real-TPU lowering would emit
Mosaic custom-calls the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md). VMEM sizing for a real TPU is estimated in
DESIGN.md §Perf: 3 tiles x L x 4B per grid step.

Key-domain contract: keys are ``int32`` strictly below ``INT32_MAX``
(the maximum is reserved as the window padding sentinel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Padding sentinel: +inf for int32 keys.
INT32_INF = jnp.iinfo(jnp.int32).max


def _partition_kernel(a_ref, b_ref, starts_ref, *, segment_len: int):
    """Compute merge-path intersections for all grid diagonals at once.

    starts_ref has shape (G + 1, 2): row g is (a_start, b_start) of
    segment g; row G is (|A|, |B|).
    """
    a = a_ref[...]
    b = b_ref[...]
    n_a = a.shape[0]
    n_b = b.shape[0]
    g_plus_1 = starts_ref.shape[0]
    # Diagonal of row g (the last row's diagonal is exactly n_a + n_b).
    diag = jnp.minimum(
        jnp.arange(g_plus_1, dtype=jnp.int32) * segment_len, n_a + n_b
    )
    lo = jnp.maximum(diag - n_b, 0)
    hi = jnp.minimum(diag, n_a)
    # Degenerate one-sided inputs: the intersection is forced (shapes
    # are static, so this is a trace-time branch — no gathers emitted
    # against an empty operand).
    if n_a == 0 or n_b == 0:
        starts_ref[...] = jnp.stack([hi, diag - hi], axis=1).astype(jnp.int32)
        return
    # Branch-free binary search, identical invariants to the rust
    # diagonal_intersection: find the smallest a-count not in the first
    # `diag` outputs.
    steps = max(1, int(n_a).bit_length() + 1)
    for _ in range(steps):
        active = lo < hi
        mid = lo + (hi - lo) // 2
        # Safe gathers (indices clipped; results ignored when inactive).
        a_mid = a[jnp.clip(mid, 0, n_a - 1)]
        b_idx = jnp.clip(diag - 1 - mid, 0, n_b - 1)
        b_val = b[b_idx]
        pred = a_mid <= b_val  # A[mid] lands inside the first diag outputs
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
    starts_ref[...] = jnp.stack([lo, diag - lo], axis=1).astype(jnp.int32)


def partition_call(a, b, segment_len: int):
    """Run the partition kernel: returns (G + 1, 2) int32 start points."""
    n = a.shape[0] + b.shape[0]
    num_segments = -(-n // segment_len) if n else 1
    # Degenerate one-sided shapes: the Pallas interpreter rejects
    # zero-length operands, and the intersection is forced anyway —
    # compute it in plain jnp at trace time.
    if a.shape[0] == 0 or b.shape[0] == 0:
        diag = jnp.minimum(
            jnp.arange(num_segments + 1, dtype=jnp.int32) * segment_len, n
        )
        a_cnt = jnp.minimum(diag, a.shape[0])
        return jnp.stack([a_cnt, diag - a_cnt], axis=1).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_partition_kernel, segment_len=segment_len),
        out_shape=jax.ShapeDtypeStruct((num_segments + 1, 2), jnp.int32),
        interpret=True,
    )(a, b)


def _merge_block_kernel(a_win_ref, b_win_ref, ka_ref, kb_ref, o_ref):
    """Merge one path segment from its A/B windows (see module docs).

    a_win/b_win: (1, L) window blocks (one grid row) starting at the
    segment's path point, padded with INT32_INF past the end of the
    source array.
    ka/kb: (1,) consumed-element counts: ka + kb == L (interior
    segments) or the residual for the last one.
    """
    a = a_win_ref[0, :]
    b = b_win_ref[0, :]
    ka = ka_ref[0]
    kb = kb_ref[0]
    length = a.shape[0]
    idx = jnp.arange(length, dtype=jnp.int32)
    a_valid = jnp.where(idx < ka, a, INT32_INF)
    b_valid = jnp.where(idx < kb, b, INT32_INF)
    # Stable A-priority ranks (see module docstring).
    pos_a = idx + jnp.searchsorted(b_valid, a_valid, side="left").astype(jnp.int32)
    pos_b = idx + jnp.searchsorted(a_valid, b_valid, side="right").astype(jnp.int32)
    pos_a = jnp.where(idx < ka, pos_a, length)  # drop invalid lanes
    pos_b = jnp.where(idx < kb, pos_b, length)
    out = jnp.full((length,), INT32_INF, dtype=a.dtype)
    out = out.at[pos_a].set(a_valid, mode="drop")
    out = out.at[pos_b].set(b_valid, mode="drop")
    o_ref[0, :] = out


def merge_blocks_call(a_windows, b_windows, ka, kb):
    """Merge all segments: (G, L) windows -> (G, L) merged blocks.

    The grid dimension is the path segment (the GPU threadblock / the
    paper's cache segment); BlockSpec stages one (L,) window of each
    input per grid step — the HBM->VMEM schedule of DESIGN.md
    §Hardware-Adaptation.
    """
    g, length = a_windows.shape
    return pl.pallas_call(
        _merge_block_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, length), lambda i: (i, 0)),
            pl.BlockSpec((1, length), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, length), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, length), a_windows.dtype),
        interpret=True,
    )(a_windows, b_windows, ka, kb)
