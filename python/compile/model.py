"""L2: the full Merge-Path compute graph in JAX, calling the L1 Pallas
kernels. Lowered once by aot.py; never imported at serve time.

The graph mirrors the paper's two phases:

1. ``partition_call`` (Pallas) — start points of every path segment.
2. window gather — for each segment, a static-size ``L`` window of each
   input starting at its path point (Lemma 16 guarantees a length-L
   segment needs at most L consecutive elements of each input). Inputs
   are padded with the INT32_INF sentinel so windows near the array end
   stay in bounds.
3. ``merge_blocks_call`` (Pallas, grid over segments) — rank-based
   merge of each window pair; blocks concatenate to the merged array
   (Thm 5).

Shapes are static (XLA requirement): one artifact per (|A|, |B|, L).
"""

import jax
import jax.numpy as jnp

from .kernels.merge_path import (
    INT32_INF,
    merge_blocks_call,
    partition_call,
)


def merge_model(n_a: int, n_b: int, segment_len: int):
    """Build the merge function for fixed sizes; returns a traceable fn
    of (a: i32[n_a], b: i32[n_b]) -> (i32[n_a + n_b],).

    ``segment_len`` must divide into the output usefully; the last
    segment may be short (masked inside the kernel).
    """
    n = n_a + n_b
    num_segments = max(1, -(-n // segment_len))

    def merge(a, b):
        starts = partition_call(a, b, segment_len)  # (G+1, 2)
        a_starts = starts[:-1, 0]
        b_starts = starts[:-1, 1]
        ka = starts[1:, 0] - starts[:-1, 0]  # per-segment A consumption
        kb = starts[1:, 1] - starts[:-1, 1]
        # Pad inputs so every window gather is in bounds.
        a_pad = jnp.concatenate(
            [a, jnp.full((segment_len,), INT32_INF, dtype=a.dtype)]
        )
        b_pad = jnp.concatenate(
            [b, jnp.full((segment_len,), INT32_INF, dtype=b.dtype)]
        )
        gather = lambda arr, s: jax.lax.dynamic_slice(arr, (s,), (segment_len,))
        a_windows = jax.vmap(lambda s: gather(a_pad, s))(a_starts)  # (G, L)
        b_windows = jax.vmap(lambda s: gather(b_pad, s))(b_starts)
        blocks = merge_blocks_call(a_windows, b_windows, ka, kb)  # (G, L)
        merged = blocks.reshape(-1)[:n]
        return (merged,)

    merge.num_segments = num_segments
    return merge


def merge_ref_model(n_a: int, n_b: int):
    """Plain-jnp reference graph (no Pallas): used by the HLO cost
    comparison in the perf pass and as an L2-level oracle."""

    def merge(a, b):
        pos_a = jnp.arange(n_a, dtype=jnp.int32) + jnp.searchsorted(
            b, a, side="left"
        ).astype(jnp.int32)
        pos_b = jnp.arange(n_b, dtype=jnp.int32) + jnp.searchsorted(
            a, b, side="right"
        ).astype(jnp.int32)
        out = jnp.zeros(n_a + n_b, dtype=a.dtype)
        out = out.at[pos_a].set(a)
        out = out.at[pos_b].set(b)
        return (out,)

    return merge
