"""AOT export: lower the L2 merge graphs to HLO **text** artifacts the
rust runtime loads via PJRT.

Usage (from python/): ``python -m compile.aot --outdir ../artifacts``

HLO text — NOT ``lowered.compile()`` / serialized protos: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 rust crate pins)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import merge_model, merge_ref_model

# (name, n_a, n_b, segment_len) — shapes served by the coordinator.
# Kept deliberately small: CPU-interpret Pallas inflates compile time,
# and the coordinator batches jobs into these buckets.
ARTIFACTS = [
    ("merge_1024x1024", 1024, 1024, 256),
    ("merge_4096x4096", 4096, 4096, 512),
    ("merge_16384x16384", 16384, 16384, 1024),
]

# Plain-jnp (no Pallas) variant, exported for the L2 ablation bench.
REF_ARTIFACTS = [
    ("merge_ref_4096x4096", 4096, 4096),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_merge(n_a: int, n_b: int, segment_len: int) -> str:
    fn = merge_model(n_a, n_b, segment_len)
    spec_a = jax.ShapeDtypeStruct((n_a,), jnp.int32)
    spec_b = jax.ShapeDtypeStruct((n_b,), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(spec_a, spec_b))


def lower_merge_ref(n_a: int, n_b: int) -> str:
    fn = merge_ref_model(n_a, n_b)
    spec_a = jax.ShapeDtypeStruct((n_a,), jnp.int32)
    spec_b = jax.ShapeDtypeStruct((n_b,), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(spec_a, spec_b))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest_lines = ["# name  file  op  n_a  n_b  dtype"]
    for name, n_a, n_b, seg in ARTIFACTS:
        text = lower_merge(n_a, n_b, seg)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {fname} merge {n_a} {n_b} i32")
        print(f"wrote {fname} ({len(text)} chars, L={seg})")
    for name, n_a, n_b in REF_ARTIFACTS:
        text = lower_merge_ref(n_a, n_b)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {fname} merge-ref {n_a} {n_b} i32")
        print(f"wrote {fname} ({len(text)} chars, pure-jnp ref)")

    with open(os.path.join(args.outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines) - 1} artifacts")


if __name__ == "__main__":
    main()
