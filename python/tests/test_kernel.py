"""L1 kernel correctness: Pallas kernels vs the pure oracles.

Exact integer equality everywhere — merging is not approximate.
Hypothesis sweeps shapes, duplicates and adversarial layouts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.merge_path import (
    INT32_INF,
    merge_blocks_call,
    partition_call,
)
from compile.kernels.ref import (
    merge_ref_jnp,
    merge_ref_np,
    partition_ref,
)

# Key domain: strictly below the INT32_INF sentinel (kernel contract).
KEY = st.integers(min_value=-(2**31), max_value=2**31 - 2)


def sorted_arr(values):
    return np.sort(np.asarray(values, dtype=np.int32))


# ---------------------------------------------------------------- refs


@given(st.lists(KEY, max_size=60), st.lists(KEY, max_size=60))
@settings(max_examples=60, deadline=None)
def test_ref_jnp_matches_ref_np(xs, ys):
    a, b = sorted_arr(xs), sorted_arr(ys)
    got = np.asarray(merge_ref_jnp(a, b))
    expected = merge_ref_np(a, b)
    np.testing.assert_array_equal(got, expected)


def test_ref_walk_paper_example():
    a = sorted_arr([17, 29, 35, 73, 86, 90, 95, 99])
    b = sorted_arr([3, 5, 12, 22, 45, 64, 69, 82])
    out = merge_ref_np(a, b)
    assert list(out[:8]) == [3, 5, 12, 17, 22, 29, 35, 45]


# ----------------------------------------------------- partition kernel


@given(
    st.lists(KEY, max_size=80),
    st.lists(KEY, max_size=80),
    st.integers(min_value=1, max_value=64),
)
@settings(max_examples=40, deadline=None)
def test_partition_kernel_matches_walk(xs, ys, seg):
    a, b = sorted_arr(xs), sorted_arr(ys)
    if len(a) + len(b) == 0:
        return
    got = np.asarray(partition_call(jnp.asarray(a), jnp.asarray(b), seg))
    expected = partition_ref(a, b, seg)
    np.testing.assert_array_equal(got, expected)


def test_partition_one_sided():
    a = sorted_arr(np.arange(100) + 1000)
    b = sorted_arr(np.arange(100))
    got = np.asarray(partition_call(jnp.asarray(a), jnp.asarray(b), 50))
    expected = partition_ref(a, b, 50)
    np.testing.assert_array_equal(got, expected)
    # First two segments consume only B.
    assert got[1][0] == 0 and got[1][1] == 50
    assert got[2][0] == 0 and got[2][1] == 100


def test_partition_duplicates_ties_go_to_a():
    a = sorted_arr([5] * 40)
    b = sorted_arr([5] * 40)
    got = np.asarray(partition_call(jnp.asarray(a), jnp.asarray(b), 20))
    # First 40 outputs must all come from A (stability).
    assert got[1][0] == 20 and got[1][1] == 0
    assert got[2][0] == 40 and got[2][1] == 0
    assert got[3][0] == 40 and got[3][1] == 20


# --------------------------------------------------------- merge kernel


def run_full_merge(a, b, seg):
    """Drive the two kernels the way model.py does (numpy gather)."""
    a_j, b_j = jnp.asarray(a), jnp.asarray(b)
    starts = np.asarray(partition_call(a_j, b_j, seg))
    g = starts.shape[0] - 1
    a_pad = np.concatenate([a, np.full(seg, INT32_INF, dtype=np.int32)])
    b_pad = np.concatenate([b, np.full(seg, INT32_INF, dtype=np.int32)])
    a_w = np.stack([a_pad[s : s + seg] for s in starts[:-1, 0]])
    b_w = np.stack([b_pad[s : s + seg] for s in starts[:-1, 1]])
    ka = (starts[1:, 0] - starts[:-1, 0]).astype(np.int32)
    kb = (starts[1:, 1] - starts[:-1, 1]).astype(np.int32)
    blocks = np.asarray(
        merge_blocks_call(jnp.asarray(a_w), jnp.asarray(b_w), jnp.asarray(ka), jnp.asarray(kb))
    )
    assert blocks.shape == (g, seg)
    return blocks.reshape(-1)[: len(a) + len(b)]


@given(
    st.lists(KEY, max_size=100),
    st.lists(KEY, max_size=100),
    st.sampled_from([1, 2, 7, 16, 64]),
)
@settings(max_examples=40, deadline=None)
def test_merge_kernel_matches_ref(xs, ys, seg):
    a, b = sorted_arr(xs), sorted_arr(ys)
    if len(a) + len(b) == 0:
        return
    got = run_full_merge(a, b, seg)
    expected = merge_ref_np(a, b)
    np.testing.assert_array_equal(got, expected)


@pytest.mark.parametrize("seg", [4, 32, 256])
@pytest.mark.parametrize(
    "case",
    [
        "one_sided",
        "interleaved",
        "all_equal",
        "empty_a",
        "empty_b",
        "unequal",
    ],
)
def test_merge_kernel_adversarial(case, seg):
    rng = np.random.default_rng(7)
    if case == "one_sided":
        a = sorted_arr(np.arange(200) + 10_000)
        b = sorted_arr(np.arange(300))
    elif case == "interleaved":
        a = sorted_arr(np.arange(250) * 2)
        b = sorted_arr(np.arange(250) * 2 + 1)
    elif case == "all_equal":
        a = sorted_arr([42] * 128)
        b = sorted_arr([42] * 200)
    elif case == "empty_a":
        a = sorted_arr([])
        b = sorted_arr(rng.integers(-1000, 1000, 157))
    elif case == "empty_b":
        a = sorted_arr(rng.integers(-1000, 1000, 157))
        b = sorted_arr([])
    else:  # unequal
        a = sorted_arr(rng.integers(-(2**30), 2**30, 13))
        b = sorted_arr(rng.integers(-(2**30), 2**30, 499))
    got = run_full_merge(a, b, seg)
    np.testing.assert_array_equal(got, merge_ref_np(a, b))


def test_merge_kernel_extreme_keys():
    # Keys at the edges of the allowed domain (INT32_INF - 1 is legal).
    a = sorted_arr([-(2**31), -(2**31), 0, 2**31 - 2])
    b = sorted_arr([-(2**31), 2**31 - 2, 2**31 - 2])
    got = run_full_merge(a, b, 4)
    np.testing.assert_array_equal(got, merge_ref_np(a, b))
