"""AOT export checks: HLO text generation and the manifest contract
with the rust runtime (no PJRT execution here — rust integration tests
cover the load-and-run side)."""

import os

import pytest

from compile.aot import ARTIFACTS, lower_merge, to_hlo_text


def test_lower_merge_produces_hlo_text():
    text = lower_merge(256, 256, 64)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Two s32 parameters of the right shape.
    assert "s32[256]" in text


def test_hlo_text_has_tuple_root():
    # return_tuple=True: the rust side unwraps with to_tuple1().
    text = lower_merge(128, 128, 32)
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert root_lines, text[:500]
    assert any("tuple" in l or "(s32[" in l for l in root_lines)


def test_artifact_table_is_sane():
    names = [a[0] for a in ARTIFACTS]
    assert len(set(names)) == len(names), "duplicate artifact names"
    for name, n_a, n_b, seg in ARTIFACTS:
        assert n_a > 0 and n_b > 0 and seg > 0
        assert seg <= n_a + n_b
        assert str(n_a) in name


@pytest.mark.skipif(
    not os.path.exists(os.path.join("..", "artifacts", "manifest.txt")),
    reason="run `make artifacts` first",
)
def test_written_manifest_matches_artifact_table():
    with open(os.path.join("..", "artifacts", "manifest.txt")) as f:
        lines = [
            l.split()
            for l in f.read().splitlines()
            if l.strip() and not l.startswith("#")
        ]
    by_name = {l[0]: l for l in lines}
    for name, n_a, n_b, _seg in ARTIFACTS:
        assert name in by_name, f"{name} missing from manifest"
        entry = by_name[name]
        assert entry[2] == "merge"
        assert int(entry[3]) == n_a and int(entry[4]) == n_b
        # The artifact file itself exists and is HLO text.
        path = os.path.join("..", "artifacts", entry[1])
        assert os.path.exists(path)
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head


def test_to_hlo_text_rejects_nothing_weird():
    # Smoke: a trivial jitted fn lowers through the same path.
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda x: (x + 1,)).lower(
        jax.ShapeDtypeStruct((8,), jnp.int32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
