"""L2 model correctness: the full jax graph (partition + window gather
+ block merge) vs the numpy oracle, plus shape/lowering checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import merge_model, merge_ref_model
from compile.kernels.ref import merge_ref_np


def sorted_keys(rng, n, lo=-(2**30), hi=2**30):
    return np.sort(rng.integers(lo, hi, n).astype(np.int32))


@pytest.mark.parametrize(
    "n_a,n_b,seg",
    [
        (64, 64, 16),
        (128, 128, 32),
        (100, 156, 32),  # n not divisible by seg
        (1024, 1024, 256),  # an exported artifact shape
        (256, 0, 64),
        (0, 256, 64),
    ],
)
def test_merge_model_matches_oracle(n_a, n_b, seg):
    rng = np.random.default_rng(n_a * 31 + n_b)
    a = sorted_keys(rng, n_a)
    b = sorted_keys(rng, n_b)
    fn = merge_model(n_a, n_b, seg)
    (got,) = jax.jit(fn)(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), merge_ref_np(a, b))


def test_merge_model_one_sided():
    n = 512
    a = np.arange(n, dtype=np.int32) + 100_000
    b = np.arange(n, dtype=np.int32)
    fn = merge_model(n, n, 128)
    (got,) = jax.jit(fn)(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), merge_ref_np(a, b))


def test_merge_model_duplicates():
    a = np.full(300, 7, dtype=np.int32)
    b = np.full(212, 7, dtype=np.int32)
    fn = merge_model(300, 212, 64)
    (got,) = jax.jit(fn)(jnp.asarray(a), jnp.asarray(b))
    assert (np.asarray(got) == 7).all()


def test_ref_model_matches_oracle():
    rng = np.random.default_rng(5)
    a = sorted_keys(rng, 200)
    b = sorted_keys(rng, 300)
    fn = merge_ref_model(200, 300)
    (got,) = jax.jit(fn)(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), merge_ref_np(a, b))


def test_model_output_shape_and_dtype():
    fn = merge_model(128, 64, 32)
    out = jax.eval_shape(
        fn,
        jax.ShapeDtypeStruct((128,), jnp.int32),
        jax.ShapeDtypeStruct((64,), jnp.int32),
    )
    assert out[0].shape == (192,)
    assert out[0].dtype == jnp.int32
    assert fn.num_segments == 6


def test_model_lowers_to_stablehlo():
    fn = merge_model(256, 256, 64)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((256,), jnp.int32),
        jax.ShapeDtypeStruct((256,), jnp.int32),
    )
    text = str(lowered.compiler_ir("stablehlo"))
    assert "func" in text
